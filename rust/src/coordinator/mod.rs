//! L3 coordinator — the paper's system contribution.
//!
//! Since the RoundEngine refactor the layer splits into one **engine**
//! that owns the training lifecycle and small **algorithm strategies**
//! that parameterize it; since the event-fabric refactor the engine
//! drives that lifecycle in one of two **communication modes** over a
//! single report event stream:
//!
//! ```text
//!              RoundEngine (engine.rs)
//!   session open · dataset build/shard · worker spawn
//!   scoping/LR schedules · eval cadence · checkpoint/resume
//!   curve + RunRecord · shutdown
//!   ┌─ sync:  round barrier — broadcast · collect-all · reduce
//!   └─ async: event loop — AsyncPacer dispatches per replica,
//!             elastic partial update per arriving report,
//!             max_staleness bounds the lead over the slowest
//!        │                                   ▲
//!        │ RoundAlgo trait                   │ results
//!        ▼                                   │
//!   ┌───────────────┬───────────────┬────────────────┐
//!   │ CoupledAlgo   │ GradAvgAlgo   │ HierarchyAlgo  │
//!   │ (driver.rs)   │ (sgd_dp.rs)   │ (hierarchy.rs) │
//!   │ Parle/Entropy │ data-parallel │ deputies under │
//!   │ /Elastic/SGD  │ SGD baseline  │ a sheriff §3.2 │
//!   └───────────────┴───────────────┴────────────────┘
//!        │ workers: run_replica / grad_worker (replica.rs)
//!        ▼
//!              ReduceFabric (comm.rs)
//!   rounds · double-buffered slabs · recycled report buffers
//!   broadcast / send_round_to · collect / recv_report · reduce
//!   bucketed streaming reduce in sync mode (--reduce-bucket-bytes)
//!   snapshot/restore barrier · per-replica exposed-wait (wait.r<id>)
//!        │
//!        │ Transport trait (transport/) — the dispatch and report legs
//!        ▼
//!   ┌─────────────────────────────┬──────────────────────────────┐
//!   │ ChannelTransport (default)  │ TcpTransport (transport/tcp) │
//!   │ in-process MPSC channels    │ length-prefixed wire codec   │
//!   │ zero-copy Arc payloads      │ (transport/wire, reuses the  │
//!   │ simulated interconnect      │ checkpoint section encoding) │
//!   │ P*4 logical bytes metered   │ post-encode bytes metered;   │
//!   │ --wire-codec ignored:       │ --wire-codec payload         │
//!   │ no wire to compress         │ transforms (transport/codec) │
//!   │ workers = threads           │ workers = processes that     │
//!   │                             │ connect (serve_worker) and   │
//!   │                             │ run the SAME worker bodies   │
//!   └─────────────────────────────┴──────────────────────────────┘
//! ```
//!
//! Topology: `n` replica worker **threads**, each owning a private PJRT
//! [`crate::runtime::Session`] (one "device" per replica, exactly the
//! paper's one-GPU-per-replica layout), plus the master thread that owns
//! the reference variable `x`, the scoping schedule, and the
//! communication fabric. Evaluation gets its own thread + session
//! (`overlap_eval`, default on) so the validation sweep overlaps the
//! next round's compute instead of extending the round barrier.
//!
//! A communication **round** = `L` inner minibatch steps on a replica
//! followed by one exchange with the master. In `--comm-mode sync`
//! (default, the paper's algorithm) the exchange is a barrier:
//!
//! ```text
//!  master ──(xref, lr, 1/γ, 1/ρ)──▶ replica a      [broadcast, O(N)]
//!  replica a: L × inner_step artifact (8a)+(8b)    [compute]
//!             outer step (8c) host-side            [O(N) vector op]
//!  replica a ──(x^a, loss stats)──▶ master         [reduce, O(N)]
//!  master: x ← mean_a x^a (8d), scoping.step() (9) [reduce]
//! ```
//!
//! With `--reduce-bucket-bytes N` (default 16 MiB) the sync exchange
//! *streams*: both legs split the parameter vector into fixed-size
//! buckets, and the master folds bucket `k` into the running mean the
//! moment every replica's copy of `k` has arrived — the reduce
//! overlaps the collection wait instead of following it. Per-element
//! accumulation order is unchanged, so the bucketed round is
//! bit-identical to the monolithic one for every bucket size (`0`
//! restores whole-vector rounds). Async dispatches stay monolithic:
//! each reply reduces alone, so there is nothing to overlap with.
//!
//! In `--comm-mode async` (the elastic averaging variant the paper's
//! loose coupling admits — Zhang et al. 2015; staleness tolerance per
//! Yu et al. 2018) there is no barrier: the master hands each replica
//! its next leg the moment it reports, applies the eq. (5)-style
//! partial update `x ← x + β (x^a − x)` per arriving report, and holds
//! back any replica more than `max_staleness` rounds ahead of the
//! slowest. Cadenced work (scoping, eval, checkpoints) keys off the
//! *watermark* — rounds completed by every replica — so those counts
//! stay deterministic even though the update order is not.
//!
//! All four algorithms in the paper are projections of this loop — see
//! [`spec::CoupledSpec`]. Synchronous data-parallel SGD (the baseline)
//! runs the same engine with L = 1 and gradients as payloads
//! ([`sgd_dp::GradAvgAlgo`]; its async mode is Downpour-style gradient
//! application); the hierarchical variant runs it with one broadcast
//! group per deputy ([`hierarchy::HierarchyAlgo`]).
//!
//! **Checkpoint/resume** is round-granular: the engine periodically
//! snapshots the full training state — master + per-worker vectors,
//! RNG draw counts, per-replica round stamps (`w<id>.rounds_done`),
//! scoping round, partial curve — through the fabric's snapshot barrier
//! into a [`checkpoint::Checkpoint`]. A sync-mode `--resume` reproduces
//! the uninterrupted run's final params and curve exactly; an async
//! resume continues each replica at its own round stamp (cadence fields
//! stay deterministic, the trajectory is not replayable by design).
//! Over TCP the snapshot barrier runs at the same quiescent points —
//! the engine drains every in-flight remote leg first — so remote
//! worker state checkpoints and restores exactly like local state.
//!
//! **Distributed runs** (`--transport tcp`): the master process runs
//! the engine over a [`transport::TcpTransport`]; each worker process
//! runs [`driver::serve_worker`] (`--role worker --connect host:port`)
//! with the same config, rebuilds its data shard locally from the slot
//! the handshake assigns, and drives the same worker body it would run
//! as a thread. Sync-mode final params and curves are bit-identical
//! across transports. `--wire-codec` (negotiated in the handshake;
//! mismatched workers are refused at connect) applies a payload
//! transform to both wire legs — bf16/f16 quantization, top-k report
//! sparsification, XOR-delta broadcasts — with per-replica
//! error-feedback residuals on the lossy report leg that ride worker
//! snapshots (`wire.ef`), so checkpoint/resume stays
//! trajectory-stable; `raw` (default) and `delta` are bit-identical
//! to the uncoded wire.
//!
//! **Invariants (machine-checked).** This layer carries the invariants
//! `pallas-lint` enforces (`cargo run --bin pallas_lint`, rules in
//! [`crate::lint::rules`], CI-gated):
//!
//! * *Determinism (D1/D2)*: everything on the reduce path —
//!   `comm.rs`, `engine.rs`, `checkpoint.rs`, `transport/wire.rs`,
//!   `opt/vecmath.rs` — iterates in replica order, never through hash
//!   containers, and never truncates a seed or replica id with `as`.
//! * *Steady-state allocation (A1)*: the fabric's per-round legs
//!   (`// lint: hot-path` regions in `comm.rs` and `transport/tcp.rs`)
//!   only recycle — broadcast slabs via `Arc::make_mut`, report slabs
//!   via the replica-indexed pool; warmup allocation lives in cold
//!   `ensure_*` helpers.
//! * *Panic-safety (P1)*: worker bodies (`replica.rs`), the TCP
//!   reader threads and the master's event-loop receive
//!   (`// lint: panic-free` regions) propagate errors as
//!   `FabricEvent::Failed`/`Exited` — a panic there is observed as a
//!   hang, never an error.
//! * *Wire bounds (W1)*: every length decoded in `transport/wire.rs`,
//!   `transport/codec.rs` or `checkpoint.rs` passes a named `MAX_*`
//!   cap before it sizes an allocation.
//!
//! The concurrency protocols themselves (AsyncPacer's staleness bound,
//! shutdown with reports in flight) are exhaustively model-checked in
//! `tests/loom_model.rs` (`--features loom-check`).

pub mod checkpoint;
pub mod comm;
pub mod driver;
pub mod engine;
pub mod hierarchy;
pub mod replica;
pub mod sgd_dp;
pub mod spec;
pub mod transport;

pub use checkpoint::Checkpoint;
pub use comm::ReduceFabric;
pub use driver::{serve_worker, train, TrainOutput};
pub use engine::{serve_worker_as, RoundAlgo, RoundEngine};
pub use hierarchy::train_hierarchical;
pub use spec::CoupledSpec;
pub use transport::{TcpTransport, TcpWorkerLink, Transport};

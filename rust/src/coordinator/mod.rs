//! L3 coordinator — the paper's system contribution.
//!
//! Since the RoundEngine refactor the layer splits into one **engine**
//! that owns the training lifecycle and small **algorithm strategies**
//! that parameterize it:
//!
//! ```text
//!              RoundEngine (engine.rs)
//!   session open · dataset build/shard · worker spawn
//!   round loop · scoping/LR schedules · eval cadence
//!   checkpoint/resume · curve + RunRecord · shutdown
//!        │                                   ▲
//!        │ RoundAlgo trait                   │ results
//!        ▼                                   │
//!   ┌───────────────┬───────────────┬────────────────┐
//!   │ CoupledAlgo   │ GradAvgAlgo   │ HierarchyAlgo  │
//!   │ (driver.rs)   │ (sgd_dp.rs)   │ (hierarchy.rs) │
//!   │ Parle/Entropy │ sync data-    │ deputies under │
//!   │ /Elastic/SGD  │ parallel SGD  │ a sheriff §3.2 │
//!   └───────────────┴───────────────┴────────────────┘
//!        │ workers: run_replica / grad_worker (replica.rs)
//!        ▼
//!              ReduceFabric (comm.rs)
//!   broadcast/collect/reduce · snapshot/restore barrier
//!   double-buffered slabs · recycled report buffers
//!   simulated interconnect · byte metering
//! ```
//!
//! Topology: `n` replica worker **threads**, each owning a private PJRT
//! [`crate::runtime::Session`] (one "device" per replica, exactly the
//! paper's one-GPU-per-replica layout), plus the master thread that owns
//! the reference variable `x`, the scoping schedule, and the
//! reduce/broadcast fabric. Evaluation gets its own thread + session
//! (`overlap_eval`, default on) so the validation sweep overlaps the
//! next round's compute instead of extending the round barrier.
//!
//! A communication **round** = `L` inner minibatch steps on every replica
//! followed by one exchange with the master:
//!
//! ```text
//!  master ──(xref, lr, 1/γ, 1/ρ)──▶ replica a      [broadcast, O(N)]
//!  replica a: L × inner_step artifact (8a)+(8b)    [compute]
//!             outer step (8c) host-side            [O(N) vector op]
//!  replica a ──(x^a, loss stats)──▶ master         [reduce, O(N)]
//!  master: x ← mean_a x^a (8d), scoping.step() (9) [reduce]
//! ```
//!
//! All four algorithms in the paper are projections of this loop — see
//! [`spec::CoupledSpec`]. Synchronous data-parallel SGD (the baseline)
//! runs the same engine with L = 1 and gradients as payloads
//! ([`sgd_dp::GradAvgAlgo`]); the hierarchical variant runs it with one
//! broadcast group per deputy ([`hierarchy::HierarchyAlgo`]).
//!
//! **Checkpoint/resume** is round-granular: the engine periodically
//! snapshots the full training state — master + per-worker vectors,
//! RNG draw counts, scoping round, partial curve — through the fabric's
//! snapshot barrier into a [`checkpoint::Checkpoint`], and `--resume`
//! reproduces the uninterrupted run's final params and curve exactly.

pub mod checkpoint;
pub mod comm;
pub mod driver;
pub mod engine;
pub mod hierarchy;
pub mod replica;
pub mod sgd_dp;
pub mod spec;

pub use checkpoint::Checkpoint;
pub use comm::ReduceFabric;
pub use driver::{train, TrainOutput};
pub use engine::{RoundAlgo, RoundEngine};
pub use hierarchy::train_hierarchical;
pub use spec::CoupledSpec;

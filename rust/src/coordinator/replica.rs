//! Replica worker: one OS thread, one private PJRT session, one shard of
//! data, one copy of the model state.
//!
//! Owns the triple (y, z, mom) the inner artifact evolves plus — for
//! algorithms with an outer step — the outer iterate x^a and its Nesterov
//! velocity. All heavy math happens inside the AOT artifacts; this thread
//! just moves flat vectors and talks to the master through channels.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::comm::{ReplicaEndpoint, RoundConsts, RoundMsg,
                               RoundReport};
use crate::coordinator::spec::{Anchor, CoupledSpec, Gain};
use crate::data::batcher::{Augment, Batcher};
use crate::data::Dataset;
use crate::opt::vecmath;
use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32,
                     Session};
use crate::util::timer::Timer;

/// Static configuration of one replica thread.
#[derive(Clone)]
pub struct ReplicaCfg {
    pub id: usize,
    pub model: String,
    pub artifacts_dir: String,
    pub spec: CoupledSpec,
    pub l_steps: usize,
    pub alpha: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub use_scan: bool,
    pub augment: Augment,
    /// Per-replica stream seed (data order, dropout).
    pub seed: u64,
    /// Shared initialization seed (same for every replica + master).
    pub init_seed: u64,
    /// Inner-loop learning rate η′ fixed to the initial LR for
    /// Entropy-SGD/Parle (§3.1); algorithms without an outer step anneal
    /// the inner LR directly (lr arrives via RoundCmd).
    pub fixed_inner_lr: Option<f32>,
}

/// Start-of-round reset of the inner trajectory (y, z). Entropy-SGD and
/// Parle restart from the replica's own outer variable x^a; hierarchical
/// eq. (10) workers are reference-anchored and restart from the broadcast
/// reference — their DEPUTY (the y^b update's re-initialization).
pub fn round_reset(
    spec: &CoupledSpec,
    y: &mut [f32],
    z: &mut [f32],
    x_a: &[f32],
    xref: &[f32],
) {
    if !spec.reset_y {
        return;
    }
    let src = match spec.anchor {
        Anchor::Reference => xref,
        Anchor::SelfX | Anchor::None => x_a,
    };
    y.copy_from_slice(src);
    z.copy_from_slice(src);
}

/// Thread body. Runs rounds off the fabric endpoint until `Stop`.
pub fn run_replica(
    cfg: ReplicaCfg,
    dataset: Arc<Dataset>,
    ep: ReplicaEndpoint,
) -> Result<()> {
    let session = Session::open(&cfg.artifacts_dir)
        .with_context(|| format!("replica {} session", cfg.id))?;
    let mm = session.manifest.model(&cfg.model)?.clone();
    let p = mm.param_count;
    let seq_len = if mm.label_shape.is_empty() {
        0
    } else {
        mm.input_shape[0]
    };
    let mut batcher = Batcher::new(
        &dataset,
        mm.batch,
        seq_len,
        cfg.augment,
        cfg.seed,
        0x100 + cfg.id as u64,
    );

    // --- state ----------------------------------------------------------
    // All replicas start from the SAME initialization (the master's
    // seed): the quadratic coupling keeps x^a aligned *relative to where
    // they start*, and averaging dissimilar random inits is exactly the
    // failure mode §1.2 demonstrates. Replica diversity comes from data
    // order and dropout streams.
    let init = session.execute(
        &cfg.model,
        "init",
        &[lit_scalar_i32(cfg.init_seed as i32)],
    )?;
    let mut x_a = crate::runtime::to_f32(&init[0])?;
    debug_assert_eq!(x_a.len(), p);
    let mut y = x_a.clone();
    let mut z = x_a.clone();
    let mut mom = vec![0.0f32; p];
    let mut v_outer = vec![0.0f32; p];

    if cfg.use_scan && cfg.l_steps != mm.scan_l {
        bail!(
            "use_scan requires l_steps == manifest scan_l ({} != {})",
            cfg.l_steps,
            mm.scan_l
        );
    }

    // --- round loop -------------------------------------------------------
    while let Some(msg) = ep.recv() {
        let RoundMsg {
            round,
            xref,
            mut slab,
            consts,
        } = msg;
        let RoundConsts {
            lr,
            gamma_inv,
            rho_inv,
            ..
        } = consts;

        round_reset(&cfg.spec, &mut y, &mut z, &x_a, &xref);
        // Elastic-SGD replicas track the reference between rounds through
        // the proximal term only; their iterate persists.

        let gain = match cfg.spec.gain {
            Gain::GammaInv => gamma_inv,
            Gain::RhoInv => rho_inv,
            Gain::Zero => 0.0,
        };
        let inner_lr = cfg.fixed_inner_lr.unwrap_or(lr);

        let timer = Timer::new();
        let (loss_sum, err_sum, steps_done) = if cfg.use_scan {
            run_scan_round(
                &session, &cfg, &mm, &mut batcher, &mut y, &mut z, &mut mom,
                &x_a, &xref, inner_lr, gain, round,
            )?
        } else {
            run_step_round(
                &session, &cfg, &mm, &mut batcher, &mut y, &mut z, &mut mom,
                &x_a, &xref, inner_lr, gain, round,
            )?
        };
        let step_s = timer.elapsed_s();

        // ---- outer update (8c), host-side -------------------------------
        if cfg.spec.outer_step {
            // eta/rho gain of the elastic term in (8c)
            let elastic = if cfg.spec.outer_elastic {
                lr * rho_inv
            } else {
                0.0
            };
            // (8c): x^a <- x^a - eta (x^a - z) - (eta/rho)(x^a - x)
            vecmath::outer_step(
                &mut x_a,
                &mut v_outer,
                &z,
                &xref,
                lr,
                elastic,
                cfg.momentum,
            );
        } else {
            // params ARE the inner iterate
            x_a.copy_from_slice(&y);
        }

        // ---- report back (the reduce payload) ----------------------------
        // fill the recycled slab instead of cloning x_a
        debug_assert_eq!(slab.len(), p);
        slab.copy_from_slice(&x_a);
        ep.report(RoundReport {
            replica: cfg.id,
            round,
            params: slab,
            train_loss: loss_sum / steps_done as f64,
            train_err: err_sum / steps_done as f64,
            step_s,
        });
    }
    Ok(())
}

/// L dispatches of the per-step artifact.
#[allow(clippy::too_many_arguments)]
fn run_step_round(
    session: &Session,
    cfg: &ReplicaCfg,
    mm: &crate::runtime::ModelManifest,
    batcher: &mut Batcher,
    y: &mut Vec<f32>,
    z: &mut Vec<f32>,
    mom: &mut Vec<f32>,
    x_a: &[f32],
    xref: &[f32],
    inner_lr: f32,
    gain: f32,
    round: u64,
) -> Result<(f64, f64, usize)> {
    let p = mm.param_count;
    let mut loss_sum = 0.0;
    let mut err_sum = 0.0;
    for step in 0..cfg.l_steps {
        let batch = batcher.next();
        let (xb, yb) = batch_literals(mm, &batch)?;
        let anchor = match cfg.spec.anchor {
            Anchor::SelfX => lit_f32(x_a, &[p])?,
            Anchor::Reference => lit_f32(xref, &[p])?,
            Anchor::None => lit_f32(y, &[p])?, // gain is 0; content unused
        };
        let seed = ((cfg.seed as i64
            ^ ((round as i64 * cfg.l_steps as i64 + step as i64) << 16)
            ^ cfg.id as i64)
            & 0x7fff_ffff) as i32;
        let outs = session.execute(
            &cfg.model,
            "inner_step",
            &[
                lit_f32(y, &[p])?,
                lit_f32(z, &[p])?,
                lit_f32(mom, &[p])?,
                anchor,
                xb,
                yb,
                lit_scalar_f32(inner_lr),
                lit_scalar_f32(gain),
                lit_scalar_f32(cfg.alpha),
                lit_scalar_f32(cfg.momentum),
                lit_scalar_f32(cfg.weight_decay),
                lit_scalar_i32(seed),
            ],
        )?;
        *y = crate::runtime::to_f32(&outs[0])?;
        *z = crate::runtime::to_f32(&outs[1])?;
        *mom = crate::runtime::to_f32(&outs[2])?;
        loss_sum += crate::runtime::tensor::scalar_f32(&outs[3])? as f64;
        err_sum += crate::runtime::tensor::scalar_f32(&outs[4])? as f64;
    }
    Ok((loss_sum, err_sum, cfg.l_steps))
}

/// One dispatch of the fused L-step scan artifact.
#[allow(clippy::too_many_arguments)]
fn run_scan_round(
    session: &Session,
    cfg: &ReplicaCfg,
    mm: &crate::runtime::ModelManifest,
    batcher: &mut Batcher,
    y: &mut Vec<f32>,
    z: &mut Vec<f32>,
    mom: &mut Vec<f32>,
    x_a: &[f32],
    xref: &[f32],
    inner_lr: f32,
    gain: f32,
    round: u64,
) -> Result<(f64, f64, usize)> {
    let p = mm.param_count;
    let l = cfg.l_steps;
    // stack L minibatches
    let mut xs_f = Vec::new();
    let mut xs_i = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..l {
        let b = batcher.next();
        xs_f.extend_from_slice(&b.x_f32);
        xs_i.extend_from_slice(&b.x_i32);
        ys.extend_from_slice(&b.y);
    }
    // images: [L, B, H, W, C]; tokens: [L, B, T]
    let (xb, yb) = if mm.input_dtype == crate::runtime::artifact::DType::I32 {
        let t = mm.input_shape[0];
        (
            lit_i32(&xs_i, &[l, mm.batch, t])?,
            lit_i32(&ys, &[l, mm.batch, t])?,
        )
    } else {
        let mut shape = vec![l, mm.batch];
        shape.extend_from_slice(&mm.input_shape);
        (lit_f32(&xs_f, &shape)?, lit_i32(&ys, &[l, mm.batch])?)
    };

    let anchor = match cfg.spec.anchor {
        Anchor::SelfX => lit_f32(x_a, &[p])?,
        Anchor::Reference => lit_f32(xref, &[p])?,
        Anchor::None => lit_f32(y, &[p])?,
    };
    let seed = ((cfg.seed as i64 ^ ((round as i64) << 20) ^ cfg.id as i64)
        & 0x7fff_ffff) as i32;
    let outs = session.execute(
        &cfg.model,
        "inner_scan",
        &[
            lit_f32(y, &[p])?,
            lit_f32(z, &[p])?,
            lit_f32(mom, &[p])?,
            anchor,
            xb,
            yb,
            lit_scalar_f32(inner_lr),
            lit_scalar_f32(gain),
            lit_scalar_f32(cfg.alpha),
            lit_scalar_f32(cfg.momentum),
            lit_scalar_f32(cfg.weight_decay),
            lit_scalar_i32(seed),
        ],
    )?;
    *y = crate::runtime::to_f32(&outs[0])?;
    *z = crate::runtime::to_f32(&outs[1])?;
    *mom = crate::runtime::to_f32(&outs[2])?;
    let losses = crate::runtime::to_f32(&outs[3])?;
    let errs = crate::runtime::to_f32(&outs[4])?;
    Ok((
        losses.iter().map(|&x| x as f64).sum(),
        errs.iter().map(|&x| x as f64).sum(),
        l,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algo;

    #[test]
    fn parle_resets_inner_state_to_own_outer_variable() {
        let spec = CoupledSpec::from_algo(Algo::Parle, 3);
        let x_a = vec![1.0f32, 2.0];
        let xref = vec![-7.0f32, -7.0];
        let mut y = vec![0.0f32; 2];
        let mut z = vec![0.0f32; 2];
        round_reset(&spec, &mut y, &mut z, &x_a, &xref);
        assert_eq!(y, x_a);
        assert_eq!(z, x_a);
    }

    #[test]
    fn elastic_inner_state_persists_across_rounds() {
        let spec = CoupledSpec::from_algo(Algo::ElasticSgd, 3);
        let x_a = vec![1.0f32, 2.0];
        let xref = vec![-7.0f32, -7.0];
        let before = vec![0.5f32, 0.25];
        let mut y = before.clone();
        let mut z = before.clone();
        round_reset(&spec, &mut y, &mut z, &x_a, &xref);
        assert_eq!(y, before);
        assert_eq!(z, before);
    }
}

/// Build (xb, yb) literals for one per-step batch.
pub fn batch_literals(
    mm: &crate::runtime::ModelManifest,
    batch: &crate::data::batcher::Batch,
) -> Result<(xla::Literal, xla::Literal)> {
    use crate::runtime::artifact::DType;
    if mm.input_dtype == DType::I32 {
        let t = mm.input_shape[0];
        Ok((
            lit_i32(&batch.x_i32, &[batch.n, t])?,
            lit_i32(&batch.y, &[batch.n, t])?,
        ))
    } else {
        let mut shape = vec![batch.n];
        shape.extend_from_slice(&mm.input_shape);
        Ok((
            lit_f32(&batch.x_f32, &shape)?,
            lit_i32(&batch.y, &[batch.n])?,
        ))
    }
}

//! Replica worker: one OS thread, one private PJRT session, one shard of
//! data, one copy of the model state.
//!
//! Owns the triple (y, z, mom) the inner artifact evolves plus — for
//! algorithms with an outer step — the outer iterate x^a and its Nesterov
//! velocity. All heavy math happens inside the AOT artifacts; this thread
//! just moves flat vectors and talks to the master through channels.
//!
//! The inner loop is device-resident: (y, z, mom), the anchor and the
//! round-constant scalars are uploaded once per round, each step's
//! outputs feed the next dispatch as `PjRtBuffer`s, and the state comes
//! back to the host once at round end for the outer step and the report.
//! Per-round host<->device traffic is therefore O(P) per leg (plus the
//! unavoidable per-step minibatches), not the O(P*L) the old
//! literal-marshalling loop paid — the same compute/communication
//! asymmetry the paper's outer loop exploits, applied one level down.
//!
//! The worker body is oblivious to the engine's communication mode: it
//! runs whatever round the fabric hands it, against whatever reference
//! that round carries. Under the synchronous barrier every replica gets
//! the same round in lockstep; under `--comm-mode async` the master
//! re-dispatches a replica the moment its report arrives, so this same
//! loop runs legs continuously against its last-seen anchor, each
//! stamped with the replica's own round index (which feeds the
//! per-step seed mixer, keeping dropout/augment streams well-defined
//! at any staleness).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::comm::{ReplicaEndpoint, RoundConsts, RoundMsg,
                               RoundReport, WorkerCmd, WorkerState};
use crate::coordinator::spec::{Anchor, CoupledSpec, Gain};
use crate::data::batcher::{Augment, Batcher};
use crate::data::Dataset;
use crate::opt::vecmath;
use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32,
                     Session};
use crate::util::timer::Timer;

/// Static configuration of one replica thread.
#[derive(Clone)]
pub struct ReplicaCfg {
    pub id: usize,
    pub model: String,
    pub artifacts_dir: String,
    pub spec: CoupledSpec,
    pub l_steps: usize,
    pub alpha: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub use_scan: bool,
    pub augment: Augment,
    /// Per-replica stream seed (data order, dropout).
    pub seed: u64,
    /// Shared initialization seed (same for every replica + master).
    pub init_seed: u64,
    /// Inner-loop learning rate η′ fixed to the initial LR for
    /// Entropy-SGD/Parle (§3.1); algorithms without an outer step anneal
    /// the inner LR directly (lr arrives via RoundCmd).
    pub fixed_inner_lr: Option<f32>,
}

/// Start-of-round reset of the inner trajectory (y, z). Entropy-SGD and
/// Parle restart from the replica's own outer variable x^a; hierarchical
/// eq. (10) workers are reference-anchored and restart from the broadcast
/// reference — their DEPUTY (the y^b update's re-initialization).
pub fn round_reset(
    spec: &CoupledSpec,
    y: &mut [f32],
    z: &mut [f32],
    x_a: &[f32],
    xref: &[f32],
) {
    if !spec.reset_y {
        return;
    }
    let src = match spec.anchor {
        Anchor::Reference => xref,
        Anchor::SelfX | Anchor::None => x_a,
    };
    y.copy_from_slice(src);
    z.copy_from_slice(src);
}

/// Thread body. Runs rounds off the fabric endpoint until `Stop`.
// lint: panic-free -- worker body: a panic here bypasses the fabric's
// Exited event path and shows up to the master as a hang, not an error
pub fn run_replica(
    cfg: ReplicaCfg,
    dataset: Arc<Dataset>,
    ep: ReplicaEndpoint,
) -> Result<()> {
    let session = Session::open(&cfg.artifacts_dir)
        .with_context(|| format!("replica {} session", cfg.id))?;
    let mm = session.manifest.model(&cfg.model)?.clone();
    let p = mm.param_count;
    let seq_len = crate::coordinator::driver::lm_seq_len(&mm);
    let mut batcher = Batcher::new(
        &dataset,
        mm.batch,
        seq_len,
        cfg.augment,
        cfg.seed,
        0x100 + cfg.id as u64,
    );

    // --- state ----------------------------------------------------------
    // All replicas start from the SAME initialization (the master's
    // seed): the quadratic coupling keeps x^a aligned *relative to where
    // they start*, and averaging dissimilar random inits is exactly the
    // failure mode §1.2 demonstrates. Replica diversity comes from data
    // order and dropout streams.
    let init = session.execute(
        &cfg.model,
        "init",
        &[lit_scalar_i32(crate::util::rng::fold_seed_i32(cfg.init_seed))],
    )?;
    let init0 = init
        .first()
        .context("model init returned no outputs")?;
    let mut x_a = crate::runtime::to_f32(init0)?;
    debug_assert_eq!(x_a.len(), p);
    let mut y = x_a.clone();
    let mut z = x_a.clone();
    let mut mom = vec![0.0f32; p];
    let mut v_outer = vec![0.0f32; p];

    if cfg.use_scan && cfg.l_steps != mm.scan_l {
        bail!(
            "use_scan requires l_steps == manifest scan_l ({} != {})",
            cfg.l_steps,
            mm.scan_l
        );
    }

    // --- round loop -------------------------------------------------------
    // Minibatches drawn so far: the checkpoint carries this count so a
    // resumed replica can replay its data/augment RNG streams exactly.
    let mut batches_drawn = 0u64;
    while let Some(cmd) = ep.recv_cmd() {
        let msg = match cmd {
            WorkerCmd::Round(msg) => msg,
            WorkerCmd::Snapshot => {
                ep.send_snapshot(WorkerState {
                    replica: cfg.id,
                    vecs: vec![
                        ("y".into(), y.clone()),
                        ("z".into(), z.clone()),
                        ("mom".into(), mom.clone()),
                        ("x_a".into(), x_a.clone()),
                        ("v_outer".into(), v_outer.clone()),
                    ],
                    batches_drawn,
                });
                continue;
            }
            WorkerCmd::Restore(st) => {
                for (name, dst) in [
                    ("y", &mut y),
                    ("z", &mut z),
                    ("mom", &mut mom),
                    ("x_a", &mut x_a),
                    ("v_outer", &mut v_outer),
                ] {
                    let src = st.vec(name).with_context(|| {
                        format!("replica {}: restore missing {name}", cfg.id)
                    })?;
                    if src.len() != p {
                        bail!(
                            "replica {}: restored {name} has {} params, \
                             model has {p}",
                            cfg.id,
                            src.len()
                        );
                    }
                    dst.copy_from_slice(src);
                }
                if st.batches_drawn < batches_drawn {
                    bail!(
                        "replica {}: cannot rewind batcher ({} drawn, \
                         checkpoint says {})",
                        cfg.id,
                        batches_drawn,
                        st.batches_drawn
                    );
                }
                batcher.skip_batches(st.batches_drawn - batches_drawn);
                batches_drawn = st.batches_drawn;
                continue;
            }
        };
        let RoundMsg {
            round,
            xref,
            mut slab,
            consts,
        } = msg;
        let RoundConsts {
            lr,
            gamma_inv,
            rho_inv,
            ..
        } = consts;

        round_reset(&cfg.spec, &mut y, &mut z, &x_a, &xref);
        // Elastic-SGD replicas track the reference between rounds through
        // the proximal term only; their iterate persists.

        let gain = match cfg.spec.gain {
            Gain::GammaInv => gamma_inv,
            Gain::RhoInv => rho_inv,
            Gain::Zero => 0.0,
        };
        let inner_lr = cfg.fixed_inner_lr.unwrap_or(lr);

        let timer = Timer::new();
        let (loss_sum, err_sum, steps_done) = if cfg.use_scan {
            run_scan_round(
                &session, &cfg, &mm, &mut batcher, &mut y, &mut z, &mut mom,
                &x_a, &xref, inner_lr, gain, round,
            )?
        } else {
            run_step_round(
                &session, &cfg, &mm, &mut batcher, &mut y, &mut z, &mut mom,
                &x_a, &xref, inner_lr, gain, round,
            )?
        };
        batches_drawn += steps_done as u64;
        let step_s = timer.elapsed_s();

        if round == 0
            && cfg.id == 0
            && session.device_residency() == Some(false)
        {
            crate::warn_log!(
                "runtime returns tuple roots: inner-loop state cannot \
                 stay device-resident (traffic degrades to literal-path \
                 cost, still correct)"
            );
        }

        // ---- outer update (8c), host-side -------------------------------
        if cfg.spec.outer_step {
            // eta/rho gain of the elastic term in (8c)
            let elastic = if cfg.spec.outer_elastic {
                lr * rho_inv
            } else {
                0.0
            };
            // (8c): x^a <- x^a - eta (x^a - z) - (eta/rho)(x^a - x)
            vecmath::outer_step(
                &mut x_a,
                &mut v_outer,
                &z,
                &xref,
                lr,
                elastic,
                cfg.momentum,
            );
        } else {
            // params ARE the inner iterate
            x_a.copy_from_slice(&y);
        }

        // ---- report back (the reduce payload) ----------------------------
        // fill the recycled slab instead of cloning x_a
        debug_assert_eq!(slab.len(), p);
        slab.copy_from_slice(&x_a);
        ep.report(RoundReport {
            replica: cfg.id,
            round,
            params: slab,
            train_loss: loss_sum / steps_done as f64,
            train_err: err_sum / steps_done as f64,
            step_s,
        });
    }
    // a drained loop is a clean stop unless the wire died underneath:
    // surface the typed cause (master silence, decode failure) so the
    // worker process exits with the diagnosis
    match ep.take_link_error() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Per-step dropout/augment seed: the shared collision-resistant mixer
/// over (replica stream seed, round, replica id, step-in-round).
fn step_seed(cfg: &ReplicaCfg, round: u64, step: usize) -> i32 {
    crate::util::rng::step_seed(cfg.seed, round, cfg.id as u64, step as u64)
}

/// Round-constant operands uploaded once per round for the buffer-path
/// dispatches: the proximal anchor (None for `Anchor::None`, whose gain
/// is 0 and content unused — the y buffer stands in) and the five
/// scalar hyperparameters.
struct RoundBuffers {
    anchor: Option<xla::PjRtBuffer>,
    lr: xla::PjRtBuffer,
    gain: xla::PjRtBuffer,
    alpha: xla::PjRtBuffer,
    momentum: xla::PjRtBuffer,
    weight_decay: xla::PjRtBuffer,
}

#[allow(clippy::too_many_arguments)]
fn upload_round_consts(
    session: &Session,
    cfg: &ReplicaCfg,
    p: usize,
    x_a: &[f32],
    xref: &[f32],
    inner_lr: f32,
    gain: f32,
) -> Result<RoundBuffers> {
    let anchor = match cfg.spec.anchor {
        Anchor::SelfX => Some(session.upload(&lit_f32(x_a, &[p])?)?),
        Anchor::Reference => Some(session.upload(&lit_f32(xref, &[p])?)?),
        Anchor::None => None,
    };
    Ok(RoundBuffers {
        anchor,
        lr: session.upload(&lit_scalar_f32(inner_lr))?,
        gain: session.upload(&lit_scalar_f32(gain))?,
        alpha: session.upload(&lit_scalar_f32(cfg.alpha))?,
        momentum: session.upload(&lit_scalar_f32(cfg.momentum))?,
        weight_decay: session.upload(&lit_scalar_f32(cfg.weight_decay))?,
    })
}

/// L dispatches of the per-step artifact with device-resident state:
/// (y, z, mom) and the round constants go up once, every step uploads
/// only its minibatch + seed and downloads only the two loss/error
/// scalars, and the state comes back once after the last step.
#[allow(clippy::too_many_arguments)]
// lint: panic-free -- runs inside the worker body (see run_replica)
fn run_step_round(
    session: &Session,
    cfg: &ReplicaCfg,
    mm: &crate::runtime::ModelManifest,
    batcher: &mut Batcher,
    y: &mut Vec<f32>,
    z: &mut Vec<f32>,
    mom: &mut Vec<f32>,
    x_a: &[f32],
    xref: &[f32],
    inner_lr: f32,
    gain: f32,
    round: u64,
) -> Result<(f64, f64, usize)> {
    let p = mm.param_count;
    let mut y_buf = session.upload(&lit_f32(y, &[p])?)?;
    let mut z_buf = session.upload(&lit_f32(z, &[p])?)?;
    let mut mom_buf = session.upload(&lit_f32(mom, &[p])?)?;
    let consts =
        upload_round_consts(session, cfg, p, x_a, xref, inner_lr, gain)?;

    let mut loss_sum = 0.0;
    let mut err_sum = 0.0;
    for step in 0..cfg.l_steps {
        let batch = batcher.next();
        let (xb, yb) = batch_literals(mm, &batch)?;
        let xb_buf = session.upload(&xb)?;
        let yb_buf = session.upload(&yb)?;
        let seed_buf =
            session.upload(&lit_scalar_i32(step_seed(cfg, round, step)))?;
        let outs = session.execute_buffers(
            &cfg.model,
            "inner_step",
            &[
                &y_buf,
                &z_buf,
                &mom_buf,
                consts.anchor.as_ref().unwrap_or(&y_buf),
                &xb_buf,
                &yb_buf,
                &consts.lr,
                &consts.gain,
                &consts.alpha,
                &consts.momentum,
                &consts.weight_decay,
                &seed_buf,
            ],
        )?;
        let mut outs = outs.into_iter();
        let mut take = |name: &str| {
            outs.next()
                .with_context(|| format!("inner_step: missing {name} output"))
        };
        // state stays on device: outputs feed the next dispatch directly
        y_buf = take("y")?;
        z_buf = take("z")?;
        mom_buf = take("mom")?;
        let loss = take("loss")?;
        let err = take("err")?;
        loss_sum +=
            crate::runtime::scalar_f32(&session.download(&loss)?)? as f64;
        err_sum +=
            crate::runtime::scalar_f32(&session.download(&err)?)? as f64;
    }
    *y = crate::runtime::to_f32(&session.download(&y_buf)?)?;
    *z = crate::runtime::to_f32(&session.download(&z_buf)?)?;
    *mom = crate::runtime::to_f32(&session.download(&mom_buf)?)?;
    Ok((loss_sum, err_sum, cfg.l_steps))
}

/// One dispatch of the fused L-step scan artifact.
#[allow(clippy::too_many_arguments)]
// lint: panic-free -- runs inside the worker body (see run_replica)
fn run_scan_round(
    session: &Session,
    cfg: &ReplicaCfg,
    mm: &crate::runtime::ModelManifest,
    batcher: &mut Batcher,
    y: &mut Vec<f32>,
    z: &mut Vec<f32>,
    mom: &mut Vec<f32>,
    x_a: &[f32],
    xref: &[f32],
    inner_lr: f32,
    gain: f32,
    round: u64,
) -> Result<(f64, f64, usize)> {
    let p = mm.param_count;
    let l = cfg.l_steps;
    // stack L minibatches
    let mut xs_f = Vec::new();
    let mut xs_i = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..l {
        let b = batcher.next();
        xs_f.extend_from_slice(&b.x_f32);
        xs_i.extend_from_slice(&b.x_i32);
        ys.extend_from_slice(&b.y);
    }
    // images: [L, B, H, W, C]; tokens: [L, B, T]
    let (xb, yb) = if mm.input_dtype == crate::runtime::artifact::DType::I32 {
        let t = *mm
            .input_shape
            .first()
            .context("token model manifest has an empty input shape")?;
        (
            lit_i32(&xs_i, &[l, mm.batch, t])?,
            lit_i32(&ys, &[l, mm.batch, t])?,
        )
    } else {
        let mut shape = vec![l, mm.batch];
        shape.extend_from_slice(&mm.input_shape);
        (lit_f32(&xs_f, &shape)?, lit_i32(&ys, &[l, mm.batch])?)
    };

    let y_buf = session.upload(&lit_f32(y, &[p])?)?;
    let z_buf = session.upload(&lit_f32(z, &[p])?)?;
    let mom_buf = session.upload(&lit_f32(mom, &[p])?)?;
    let consts =
        upload_round_consts(session, cfg, p, x_a, xref, inner_lr, gain)?;
    let xb_buf = session.upload(&xb)?;
    let yb_buf = session.upload(&yb)?;
    // one seed for the whole fused round: same mixer, step slot 0
    let seed = crate::util::rng::step_seed(cfg.seed, round, cfg.id as u64, 0);
    let seed_buf = session.upload(&lit_scalar_i32(seed))?;
    let outs = session.execute_buffers(
        &cfg.model,
        "inner_scan",
        &[
            &y_buf,
            &z_buf,
            &mom_buf,
            consts.anchor.as_ref().unwrap_or(&y_buf),
            &xb_buf,
            &yb_buf,
            &consts.lr,
            &consts.gain,
            &consts.alpha,
            &consts.momentum,
            &consts.weight_decay,
            &seed_buf,
        ],
    )?;
    let mut outs = outs.into_iter();
    let mut take = |name: &str| {
        outs.next()
            .with_context(|| format!("inner_scan: missing {name} output"))
    };
    *y = crate::runtime::to_f32(&session.download(&take("y")?)?)?;
    *z = crate::runtime::to_f32(&session.download(&take("z")?)?)?;
    *mom = crate::runtime::to_f32(&session.download(&take("mom")?)?)?;
    let losses =
        crate::runtime::to_f32(&session.download(&take("losses")?)?)?;
    let errs = crate::runtime::to_f32(&session.download(&take("errs")?)?)?;
    Ok((
        losses.iter().map(|&x| x as f64).sum(),
        errs.iter().map(|&x| x as f64).sum(),
        l,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algo;

    #[test]
    fn parle_resets_inner_state_to_own_outer_variable() {
        let spec = CoupledSpec::from_algo(Algo::Parle, 3);
        let x_a = vec![1.0f32, 2.0];
        let xref = vec![-7.0f32, -7.0];
        let mut y = vec![0.0f32; 2];
        let mut z = vec![0.0f32; 2];
        round_reset(&spec, &mut y, &mut z, &x_a, &xref);
        assert_eq!(y, x_a);
        assert_eq!(z, x_a);
    }

    #[test]
    fn elastic_inner_state_persists_across_rounds() {
        let spec = CoupledSpec::from_algo(Algo::ElasticSgd, 3);
        let x_a = vec![1.0f32, 2.0];
        let xref = vec![-7.0f32, -7.0];
        let before = vec![0.5f32, 0.25];
        let mut y = before.clone();
        let mut z = before.clone();
        round_reset(&spec, &mut y, &mut z, &x_a, &xref);
        assert_eq!(y, before);
        assert_eq!(z, before);
    }
}

/// Build (xb, yb) literals for one per-step batch.
// lint: panic-free -- called from worker bodies and the master's eval
// thread; a malformed manifest must error, not panic
pub fn batch_literals(
    mm: &crate::runtime::ModelManifest,
    batch: &crate::data::batcher::Batch,
) -> Result<(xla::Literal, xla::Literal)> {
    use crate::runtime::artifact::DType;
    if mm.input_dtype == DType::I32 {
        let t = *mm
            .input_shape
            .first()
            .context("token model manifest has an empty input shape")?;
        Ok((
            lit_i32(&batch.x_i32, &[batch.n, t])?,
            lit_i32(&batch.y, &[batch.n, t])?,
        ))
    } else {
        let mut shape = vec![batch.n];
        shape.extend_from_slice(&mm.input_shape);
        Ok((
            lit_f32(&batch.x_f32, &shape)?,
            lit_i32(&batch.y, &[batch.n])?,
        ))
    }
}

//! RoundEngine: the single owner of the training lifecycle.
//!
//! Every algorithm in this repo — Parle, Entropy-SGD, Elastic-SGD,
//! plain SGD, synchronous data-parallel SGD, and the §3.2 hierarchy —
//! is one communication-round loop: local steps on workers, a barrier,
//! a master-side update, repeat. The engine owns everything that loop
//! needs (master session, dataset build/shard, worker spawn onto the
//! [`ReduceFabric`], scoping/LR schedules, eval cadence, curve and
//! [`RunRecord`] assembly, profiler/meter wiring, checkpointing,
//! shutdown); a [`RoundAlgo`] strategy owns only what distinguishes an
//! algorithm (worker bodies, broadcast references, the master update,
//! epoch accounting). `driver.rs`, `sgd_dp.rs` and `hierarchy.rs` are
//! thin strategies over this engine.
//!
//! # Round-granular checkpoint/resume
//!
//! With `cfg.checkpoint_every_rounds > 0` the engine writes a
//! [`Checkpoint`] at the matching round boundaries carrying the full
//! training state: the next round index, master params + auxiliary
//! vectors (`master.*` sections), every worker's persistent state
//! (`w<id>.*` sections + `w<id>.batches_drawn` meta, gathered through
//! the fabric's snapshot barrier), the scoping round counter, the
//! partial curve (a `curve` f64 section, 5 values per point) and the
//! accumulated wall/step/comm totals. `--resume <path>` restores all of
//! it and continues the loop at the saved round; because worker RNG
//! streams are replayed by draw count and every schedule is a pure
//! function of the round index, a resumed run produces the same final
//! params and curve as an uninterrupted one.
//!
//! # Synchronous barrier vs asynchronous event loop
//!
//! With `cfg.comm_mode == Sync` (the default) the engine runs the
//! paper's round barrier: broadcast, collect every report, one master
//! update — now expressed as the degenerate case of the fabric's event
//! stream (collect-until-all-reported), bit-identical in every
//! deterministic field to the pre-refactor barrier. With `Async` the
//! engine becomes an event loop: an [`AsyncPacer`] hands each replica
//! its next L-step leg as soon as it is allowed to run one, the master
//! applies an elastic partial update per arriving report
//! ([`RoundAlgo::async_update`]), and `cfg.max_staleness` bounds how
//! far any replica runs ahead of the slowest. Cadenced work — scoping
//! annealing, evaluation, checkpoints — keys off the **watermark**
//! (rounds completed by every replica), so cadence counts stay
//! deterministic even though the update order is not. Checkpoints in
//! either mode stamp per-replica `w<id>.rounds_done` so an async run
//! resumes each replica at its own round.
//!
//! # Overlapped evaluation
//!
//! Evaluation runs on a dedicated thread with its own PJRT session (one
//! more "device", exactly like a replica): at an eval round the engine
//! snapshots the master params and hands them over, then immediately
//! broadcasts the next round — the validation sweep overlaps the next
//! round's compute instead of extending the barrier. The
//! [`PhaseProfiler`] splits the cost: `eval` is the sweep's thread
//! time (overlapped), `eval_exposed` is the wall time the master
//! actually spent blocked waiting for a result (at drain points, or
//! when a sweep outlives a round). `cfg.overlap_eval = false` keeps the
//! old blocking behaviour (the two modes produce identical records up
//! to wall-clock).

use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use crate::config::{CommMode, RunConfig, ScopingCfg, TransportCfg};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::comm::{AsyncPacer, FabricPulse, ReduceFabric,
                               ReplicaEndpoint, RoundConsts, RoundReport,
                               WorkerState};
use crate::coordinator::transport::{TcpConnectOpts, TcpListenOpts,
                                    TcpTransport, TcpWorkerLink};
use crate::data::batcher::{Augment, Batch, Batcher};
use crate::data::{build, split_shards, Dataset};
use crate::info;
use crate::metrics::{Curve, CurvePoint, RunRecord};
use crate::opt::Scoping;
use crate::runtime::{lit_f32, ModelManifest, Session};
use crate::util::timer::{PhaseProfiler, Timer};

/// Result of a training run: record + final parameters.
pub struct TrainOutput {
    pub record: RunRecord,
    pub final_params: Vec<f32>,
}

/// One worker's thread/process body: drive a [`ReplicaEndpoint`] until
/// the master stops it. The engine spawns these as local threads on the
/// in-process transport; [`serve_worker_as`] runs one against a remote
/// master over TCP — the same body either way, which is what keeps
/// sync-mode training bit-identical across transports.
pub type WorkerBody =
    Box<dyn FnOnce(ReplicaEndpoint) -> Result<()> + Send + 'static>;

/// Per-round values the engine computes for the strategy.
pub struct RoundCtx<'a> {
    pub round: u64,
    pub lr: f32,
    pub scoping: &'a Scoping,
}

/// What distinguishes one algorithm from another under the engine: the
/// master-side state, the worker bodies, and the per-round update.
/// Everything else — the lifecycle — is the engine's.
pub trait RoundAlgo {
    /// Algorithm label recorded in [`RunRecord::algo`].
    fn name(&self) -> String;

    /// Replica -> broadcast-group map; its length is the worker count.
    fn groups(&self) -> Vec<usize>;

    /// Whether `cfg.split_data` shards the training set across workers
    /// (the hierarchy keeps the set shared).
    fn shards_data(&self) -> bool {
        true
    }

    /// Minibatches per epoch (B in the scoping schedule (9)).
    fn batches_per_epoch(&self, train_len: usize, mm: &ModelManifest)
                         -> usize;

    /// Epoch advance per communication round, in minibatches (L for the
    /// coupled algorithms, 1 for gradient averaging).
    fn steps_per_round(&self) -> f64;

    /// Eval cadence in rounds (0 = only at the end).
    fn eval_every_rounds(&self) -> u64;

    /// The worker body for fabric slot `w`; `datasets[w]` is that
    /// worker's (possibly sharded) training set. The engine spawns one
    /// per slot as local threads; a remote worker process runs exactly
    /// one, against the slot the master assigned it.
    fn worker_body(
        &self,
        w: usize,
        datasets: &[Arc<Dataset>],
        augment: Augment,
    ) -> WorkerBody;

    /// Install the seed initialization as the master state.
    fn init_master(&mut self, x0: Vec<f32>);

    /// Per-group broadcast references for the coming round.
    fn refs(&self) -> Vec<&[f32]>;

    /// Broadcast constants for the coming round: the annealed
    /// coupled-family constants by default (every strategy that uses
    /// scoping broadcasts exactly these); strategies without coupling
    /// override.
    fn consts(&self, ctx: &RoundCtx) -> RoundConsts {
        RoundConsts {
            lr: ctx.lr,
            gamma_inv: ctx.scoping.gamma_inv(),
            rho_inv: ctx.scoping.rho_inv(),
            eta_over_rho: ctx.lr * ctx.scoping.rho_inv(),
        }
    }

    /// The master-side update after the barrier (the profiler's
    /// `reduce` phase): consume the fabric's collected reports.
    fn master_update(&mut self, fabric: &ReduceFabric, ctx: &RoundCtx);

    /// Asynchronous partial update for one arriving replica report
    /// (`--comm-mode async`): apply the eq. (5)-style elastic coupling
    /// for this single replica instead of the full-barrier reduce.
    /// `ctx` is evaluated at the *report's* round stamp (replicas sit
    /// on different rounds). Strategies that cannot update
    /// incrementally keep the default error.
    fn async_update(&mut self, _report: &RoundReport, _ctx: &RoundCtx)
                    -> Result<()> {
        bail!("{} does not support --comm-mode async", self.name())
    }

    /// Current master parameters (evaluation + checkpoint snapshot).
    fn params(&self) -> &[f32];

    /// Auxiliary master state beyond [`RoundAlgo::params`], checkpointed
    /// under `master.<name>` sections.
    fn state_vecs(&self) -> Vec<(String, Vec<f32>)> {
        Vec::new()
    }

    /// Restore master state from a checkpoint (params + `master.*`
    /// sections; see [`master_vec`]). The engine has already verified
    /// `ck.params.len()` against [`RoundAlgo::params`].
    fn restore_state(&mut self, ck: &Checkpoint) -> Result<()>;

    /// Persistent state to install into a worker admitted mid-run on
    /// slot `replica` (a replacement or late joiner on the elastic TCP
    /// fabric): the coupled-family default seeds y, z and x_a from the
    /// current master params with zeroed momenta — the same state a
    /// fresh replica would reach after the first broadcast.
    /// `batches_drawn` fast-forwards the joiner's data/augment RNG
    /// streams to the run's current position. Strategies with stateless
    /// workers ignore the vectors (their Restore does).
    fn admit_worker_state(&self, replica: usize, batches_drawn: u64)
                          -> WorkerState {
        let p = self.params().len();
        let x = self.params().to_vec();
        WorkerState {
            replica,
            vecs: vec![
                ("y".into(), x.clone()),
                ("z".into(), x.clone()),
                ("mom".into(), vec![0.0; p]),
                ("x_a".into(), x),
                ("v_outer".into(), vec![0.0; p]),
            ],
            batches_drawn,
        }
    }

    /// Consume the strategy, yielding the final parameters.
    fn into_params(self) -> Vec<f32>
    where
        Self: Sized;
}

/// The engine itself: one run = `RoundEngine::new(cfg, label).run(algo)`.
pub struct RoundEngine<'a> {
    cfg: &'a RunConfig,
    label: &'a str,
}

impl<'a> RoundEngine<'a> {
    pub fn new(cfg: &'a RunConfig, label: &'a str) -> Self {
        RoundEngine { cfg, label }
    }

    /// Run the full lifecycle with `algo` supplying the algorithm.
    pub fn run<A: RoundAlgo>(self, mut algo: A) -> Result<TrainOutput> {
        let cfg = self.cfg;
        let label = self.label;
        let profiler = Arc::new(PhaseProfiler::new());

        // --- master session + data ---------------------------------------
        let master = Session::open(&cfg.artifacts_dir)?;
        let mm = master.manifest.model(&cfg.model)?.clone();
        let (train_ds, val_ds) = build(&mm.dataset, &cfg.data)?;
        let augment = default_augment(&mm.dataset);
        // Epoch accounting is pinned to the GLOBAL dataset length before
        // any sharding: see `epoch_batches`.
        let train_len = train_ds.len();

        let groups = algo.groups();
        let n_workers = groups.len();

        let b = algo.batches_per_epoch(train_len, &mm);
        let spr = algo.steps_per_round();
        let total_rounds = total_rounds(cfg.epochs, b, spr);
        let eval_every = algo.eval_every_rounds();

        let mut scoping = match cfg.scoping {
            ScopingCfg::Paper => Scoping::paper(b),
            ScopingCfg::Constant { gamma, rho } => {
                Scoping::constant(gamma, rho)
            }
        };

        // --- workers onto the fabric -------------------------------------
        // In-process (default): spawn one local worker thread per slot.
        // TCP master: bind, wait for every remote worker to connect —
        // the same bodies run in the worker processes (serve_worker),
        // so sync-mode outputs stay bit-identical across transports.
        let mut fabric = match cfg.transport {
            TransportCfg::InProcess => {
                // shards are only materialized where workers actually
                // consume them: here, or in each remote worker process
                // (serve_worker_as) on the TCP path
                let datasets = shard_datasets(
                    cfg,
                    algo.shards_data(),
                    train_ds,
                    n_workers,
                )?;
                let mut fabric = ReduceFabric::new(groups.clone(), cfg.comm);
                for w in 0..n_workers {
                    fabric.spawn_worker(
                        algo.worker_body(w, &datasets, augment),
                    )?;
                }
                fabric
            }
            TransportCfg::Tcp => {
                let addr = cfg.listen.as_deref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "--transport tcp master needs --listen host:port"
                    )
                })?;
                if !cfg.comm.is_off() {
                    crate::warn_log!(
                        "simulated interconnect profile ignored over \
                         --transport tcp (wire time is real)"
                    );
                }
                info!(
                    "{label} waiting for {n_workers} workers on {addr}"
                );
                let transport = TcpTransport::listen_with_opts(
                    addr,
                    n_workers,
                    crate::coordinator::transport::tcp::DEFAULT_ACCEPT_TIMEOUT,
                    TcpListenOpts {
                        codec: cfg.wire_codec,
                        evict_after: std::time::Duration::from_secs_f64(
                            cfg.evict_after_secs,
                        ),
                        fingerprint: Some(cfg.replay_fingerprint()),
                    },
                )?;
                ReduceFabric::with_transport(
                    groups.clone(),
                    Box::new(transport),
                )
            }
        };
        fabric.set_profiler(profiler.clone());
        // elastic membership only exists on the TCP fabric: in-process
        // worker threads share our fate, so there is nobody to evict
        let elastic = cfg.transport == TransportCfg::Tcp
            && cfg.evict_after_secs > 0.0;
        fabric.set_elastic(elastic);
        if cfg.comm_mode == CommMode::Sync {
            // stream the sync barrier in buckets so the master reduces
            // while later reports are still in flight; async dispatches
            // stay monolithic (each reply reduces alone — nothing to
            // overlap with)
            fabric.set_bucket_bytes(cfg.reduce_bucket_bytes);
        }
        let meter = fabric.meter();

        // --- master init (same artifact + seed for every algorithm) ------
        let init = master.execute(
            &cfg.model,
            "init",
            &[crate::runtime::lit_scalar_i32(
                crate::util::rng::fold_seed_i32(cfg.seed),
            )],
        )?;
        algo.init_master(crate::runtime::to_f32(&init[0])?);

        let eval_batches = Batcher::new(
            &val_ds,
            mm.batch,
            lm_seq_len(&mm),
            Augment::none(),
            cfg.seed,
            0xe,
        )
        .eval_batches();

        // --- resume -------------------------------------------------------
        let mut curve = Curve::new();
        let mut start_round = 0u64;
        // per-replica completed-round stamps (all equal in sync mode;
        // the async pacer resumes each replica at its own round)
        let mut worker_rounds: Vec<u64> = vec![0; n_workers];
        let mut wall_offset = 0.0f64;
        let mut step_seconds = 0.0f64;
        let mut comm_offset = 0u64;
        let mut last_train = (f64::NAN, f64::NAN);
        if let Some(path) = &cfg.resume_from {
            let ck = Checkpoint::load(path).with_context(|| {
                format!("loading resume checkpoint {path}")
            })?;
            if ck.model != cfg.model {
                bail!(
                    "checkpoint model {:?} != run model {:?}",
                    ck.model,
                    cfg.model
                );
            }
            let ck_workers = ck.require_meta("workers")? as usize;
            if ck_workers != n_workers {
                bail!(
                    "checkpoint has {ck_workers} workers, run has \
                     {n_workers}"
                );
            }
            // seed / algorithm / L determine worker RNG streams and the
            // round structure: resuming under different ones would
            // continue from inconsistent state with no error
            let ck_seed = ((ck.require_meta("seed_hi")? as u64) << 32)
                | (ck.require_meta("seed_lo")? as u64);
            if ck_seed != cfg.seed {
                bail!(
                    "checkpoint was written with seed {ck_seed}, run has \
                     seed {}",
                    cfg.seed
                );
            }
            let ck_l = ck.require_meta("l_steps")? as usize;
            if ck_l != cfg.l_steps {
                bail!(
                    "checkpoint was written with l_steps {ck_l}, run has \
                     {}",
                    cfg.l_steps
                );
            }
            let ck_fp = ((ck.require_meta("cfg_hi")? as u64) << 32)
                | (ck.require_meta("cfg_lo")? as u64);
            if ck_fp != cfg.replay_fingerprint() {
                bail!(
                    "checkpoint was written under different replay-\
                     relevant config (data/schedule/hyperparameters/\
                     dispatch mode) — resuming would silently diverge \
                     from the checkpointed run"
                );
            }
            let algo_tag = format!("algo.{}", algo.name());
            if ck.vec_f32(&algo_tag).is_none() {
                bail!(
                    "checkpoint algorithm does not match this run's \
                     {:?} (checkpoint tags: {:?})",
                    algo.name(),
                    ck.vecs_f32
                        .iter()
                        .filter_map(|(k, _)| k.strip_prefix("algo."))
                        .collect::<Vec<_>>()
                );
            }
            start_round = ck.require_meta("round")? as u64;
            if start_round > total_rounds {
                bail!(
                    "checkpoint round {start_round} is beyond this run's \
                     {total_rounds} rounds"
                );
            }
            worker_rounds =
                unpack_worker_rounds(&ck, n_workers, start_round)?;
            if cfg.comm_mode == CommMode::Sync
                && worker_rounds.iter().any(|&r| r != start_round)
            {
                // covers both uneven stamps and stamps that are even
                // but ahead of the frozen checkpoint round — either way
                // worker state is not at a synchronous barrier
                bail!(
                    "checkpoint per-replica round stamps \
                     (w<id>.rounds_done = {worker_rounds:?}) are not \
                     aligned with its round counter ({start_round}) — it \
                     was written mid-async run; resume it with \
                     --comm-mode async"
                );
            }
            scoping.set_rounds(ck.require_meta("scoping_rounds")? as u64);
            wall_offset = ck.meta_value("wall_s").unwrap_or(0.0);
            step_seconds = ck.meta_value("step_seconds").unwrap_or(0.0);
            comm_offset = ck.meta_value("comm_bytes").unwrap_or(0.0) as u64;
            last_train = (
                ck.meta_value("train_loss").unwrap_or(f64::NAN),
                ck.meta_value("train_err").unwrap_or(f64::NAN),
            );
            curve = curve_from_f64(ck.vec_f64("curve").unwrap_or(&[]))?;
            // phase totals continue cumulatively, so the final record's
            // comm_ratio and phases cover the whole run, not just the
            // post-resume segment
            restore_phases(&profiler, &ck);
            if ck.params.len() != algo.params().len() {
                bail!(
                    "checkpoint has {} params, model has {}",
                    ck.params.len(),
                    algo.params().len()
                );
            }
            algo.restore_state(&ck)?;
            fabric.restore_workers(unpack_worker_states(
                &ck,
                n_workers,
                algo.params().len(),
            )?)?;
            // RoundMsg.round feeds per-step seeds: stamp global indices
            fabric.set_round(start_round);
            info!(
                "{label} resuming at round {start_round}/{total_rounds} \
                 from {path}"
            );
        }

        // The run's wall clock starts here; the overlapped evaluator
        // shares it so curve points are stamped when a sweep completes,
        // not when the master harvests the result a round later.
        let wall = Timer::new();
        // With eval_every == 0 the only sweep is the final one, which
        // is drained immediately — no overlap is possible, so don't pay
        // a second session/thread for it.
        let mut evaluator = if cfg.overlap_eval && eval_every > 0 {
            drop(master); // eval thread opens its own session
            Evaluator::overlapped(
                cfg,
                eval_batches,
                profiler.clone(),
                wall.started_at(),
                wall_offset,
            )
        } else {
            Evaluator::inline(
                master,
                cfg.model.clone(),
                mm.clone(),
                eval_batches,
                profiler.clone(),
            )
        };

        // --- round loop ---------------------------------------------------
        if cfg.comm_mode == CommMode::Async {
            // Asynchronous event loop: each replica runs legs at its own
            // pace; the master consumes one report event at a time and
            // applies the strategy's elastic partial update. Cadenced
            // work keys off the watermark (rounds completed by EVERY
            // replica) so eval/checkpoint/scoping counts stay
            // deterministic even though the update order is not.
            let staleness = cfg.max_staleness as u64;
            let mut pacer =
                AsyncPacer::resume(worker_rounds, total_rounds, staleness);
            let mut completed = start_round;
            // per-replica latest train stats (feed curve points and the
            // final record; a replica that has not reported yet is NaN)
            let mut rep_loss = vec![f64::NAN; n_workers];
            let mut rep_err = vec![f64::NAN; n_workers];
            // a due checkpoint quiesces the fabric (no dispatching)
            // until every in-flight leg has drained, then writes
            let mut ckpt_due = false;
            loop {
                // cadence work unlocked by the watermark. Frozen while a
                // checkpoint is due: the drain below can advance the
                // watermark further, and the write must happen (and be
                // `{round}`-stamped) at exactly the round that requested
                // it — deferred steps are processed right after the
                // write, so nothing is skipped.
                while !ckpt_due && completed < pacer.watermark() {
                    completed += 1;
                    scoping.step();
                    if rep_loss.iter().any(|v| v.is_finite()) {
                        last_train =
                            (mean_finite(&rep_loss), mean_finite(&rep_err));
                    }
                    let is_last = completed == total_rounds;
                    if is_last || eval_due(completed - 1, eval_every) {
                        let epoch0 =
                            (completed - 1) as f64 * spr / b as f64;
                        let pending = Pending {
                            round: completed - 1,
                            total_rounds,
                            lr: cfg.lr.at(epoch0),
                            gamma: scoping.gamma(),
                            rho: scoping.rho(),
                            epoch: epoch0 + spr / b as f64,
                            train_loss: last_train.0,
                            train_err: last_train.1,
                        };
                        evaluator.request(
                            algo.params(),
                            pending,
                            &mut curve,
                            &wall,
                            wall_offset,
                            label,
                        )?;
                    }
                    if cfg.checkpoint_every_rounds > 0
                        && completed % cfg.checkpoint_every_rounds as u64
                            == 0
                    {
                        ckpt_due = true;
                    }
                }
                if ckpt_due {
                    if pacer.inflight() == 0 {
                        // quiescent: workers are parked in their command
                        // receive, the snapshot barrier is safe
                        evaluator.drain(&mut curve, label)?;
                        let path = checkpoint_path(cfg, label, completed);
                        write_checkpoint(
                            &path,
                            cfg,
                            &algo,
                            &mut fabric,
                            CkState {
                                next_round: completed,
                                rounds_done: pacer.done(),
                                scoping_rounds: scoping.rounds(),
                                wall_s: wall_offset + wall.elapsed_s(),
                                step_seconds,
                                comm_bytes: comm_offset + meter.bytes(),
                                last_train,
                                curve: &curve,
                                phases: profiler.snapshot(),
                            },
                        )?;
                        info!(
                            "{label} checkpoint round {completed} -> {path}"
                        );
                        ckpt_due = false;
                        continue;
                    }
                    // else: stop dispatching and drain a report below
                } else {
                    // elastic: admit a fingerprint-matched late joiner
                    // before dispatching; it resumes at the watermark so
                    // its lead starts at zero
                    if elastic {
                        if let Some(slot) = fabric.try_admit()? {
                            let wm = pacer.watermark();
                            let st = algo.admit_worker_state(
                                slot,
                                (wm as f64 * spr) as u64,
                            );
                            fabric.restore_replica(st)?;
                            fabric.readmit(slot)?;
                            pacer.readmit(slot, wm);
                            info!(
                                "{label} admitted replica {slot} at \
                                 round {wm}"
                            );
                        }
                    }
                    if pacer.all_done() {
                        break;
                    }
                    // refs are invariant within the iteration (updates
                    // only happen per received report, below)
                    let refs = algo.refs();
                    for r in pacer.dispatchable() {
                        let k = pacer.next_round(r);
                        let sc = scoping_at(&scoping, k);
                        let epoch = k as f64 * spr / b as f64;
                        let ctx = RoundCtx {
                            round: k,
                            lr: cfg.lr.at(epoch),
                            scoping: &sc,
                        };
                        let consts = algo.consts(&ctx);
                        fabric.send_round_to(r, k, consts,
                                             refs[groups[r]]);
                        pacer.mark_dispatched(r);
                    }
                }
                if pacer.inflight() == 0 {
                    // unreachable: the slowest unfinished replica is
                    // always dispatchable (lead 0 <= any staleness)
                    bail!("async pacer stalled with no legs in flight");
                }
                let rep = match fabric.recv_pulse()? {
                    FabricPulse::Report(rep) => rep,
                    FabricPulse::Evicted { replica, reason } => {
                        crate::warn_log!(
                            "{label} evicted replica {replica}: {reason} \
                             — continuing with {} live",
                            fabric.live_replicas()
                        );
                        pacer.evict(replica);
                        if pacer.all_evicted() {
                            bail!(
                                "every replica was evicted; nothing left \
                                 to train on"
                            );
                        }
                        continue;
                    }
                };
                // mean compute depth across replicas approximates the
                // async run's critical path (no barrier to take a max
                // over); comm_ratio stays comparable with sync runs
                step_seconds += rep.step_s / n_workers as f64;
                rep_loss[rep.replica] = rep.train_loss;
                rep_err[rep.replica] = rep.train_err;
                // lint: deterministic -- the elastic update must depend
                // only on the report and round, never on wall clock
                {
                    let sc = scoping_at(&scoping, rep.round);
                    let epoch = rep.round as f64 * spr / b as f64;
                    let ctx = RoundCtx {
                        round: rep.round,
                        lr: cfg.lr.at(epoch),
                        scoping: &sc,
                    };
                    profiler
                        .scope("reduce", || algo.async_update(&rep, &ctx))?;
                }
                pacer.on_report(rep.replica);
                fabric.recycle(rep);
            }
            if rep_loss.iter().any(|v| v.is_finite()) {
                last_train = (mean_finite(&rep_loss), mean_finite(&rep_err));
            }
        } else {
            for round in start_round..total_rounds {
                // elastic: admit a fingerprint-matched late joiner at the
                // round boundary — its state is anchored to the current
                // reference, and its batcher fast-forwarded to the
                // round's draw count, before the barrier re-counts it
                if elastic {
                    if let Some(slot) = fabric.try_admit()? {
                        let st = algo.admit_worker_state(
                            slot,
                            (round as f64 * spr) as u64,
                        );
                        fabric.restore_replica(st)?;
                        fabric.readmit(slot)?;
                        info!(
                            "{label} admitted replica {slot} at round \
                             {round}"
                        );
                    }
                }
                let epoch = round as f64 * spr / b as f64;
                let lr = cfg.lr.at(epoch);
                let ctx = RoundCtx {
                    round,
                    lr,
                    scoping: &scoping,
                };
                {
                    let refs = algo.refs();
                    fabric.broadcast(algo.consts(&ctx), &refs);
                }
                // barrier = synchronous reduce, like the paper: the
                // degenerate collect-until-all-reported of the event loop
                let stats = fabric.collect()?;
                step_seconds += stats.max_step_s;
                last_train = (stats.mean_loss, stats.mean_err);

                // lint: deterministic -- the synchronous reduce is the
                // bit-exactness anchor; no clock reads inside
                {
                    profiler.scope("reduce", || {
                        algo.master_update(&fabric, &ctx)
                    });
                }
                scoping.step();

                let is_last = round + 1 == total_rounds;
                if is_last || eval_due(round, eval_every) {
                    let pending = Pending {
                        round,
                        total_rounds,
                        lr,
                        gamma: scoping.gamma(),
                        rho: scoping.rho(),
                        // end-of-round epoch, identical across strategies
                        // so curves are comparable
                        epoch: epoch + spr / b as f64,
                        train_loss: last_train.0,
                        train_err: last_train.1,
                    };
                    evaluator.request(
                        algo.params(),
                        pending,
                        &mut curve,
                        &wall,
                        wall_offset,
                        label,
                    )?;
                }

                if cfg.checkpoint_every_rounds > 0
                    && (round + 1) % cfg.checkpoint_every_rounds as u64 == 0
                {
                    // the checkpoint must carry the curve up to this round
                    evaluator.drain(&mut curve, label)?;
                    let path = checkpoint_path(cfg, label, round + 1);
                    write_checkpoint(
                        &path,
                        cfg,
                        &algo,
                        &mut fabric,
                        CkState {
                            next_round: round + 1,
                            rounds_done: &vec![round + 1; n_workers],
                            scoping_rounds: scoping.rounds(),
                            wall_s: wall_offset + wall.elapsed_s(),
                            step_seconds,
                            comm_bytes: comm_offset + meter.bytes(),
                            last_train,
                            curve: &curve,
                            phases: profiler.snapshot(),
                        },
                    )?;
                    info!("{label} checkpoint round {} -> {path}",
                          round + 1);
                }
            }
        }

        // --- shutdown -----------------------------------------------------
        evaluator.drain(&mut curve, label)?;
        evaluator.shutdown()?;
        fabric.shutdown()?;

        let wall_s = wall_offset + wall.elapsed_s();
        let comm_s = profiler.total("reduce");
        let last = curve.last().copied().unwrap_or(CurvePoint {
            wall_s,
            epoch: cfg.epochs,
            train_loss: last_train.0,
            train_err: last_train.1,
            val_err: f64::NAN,
        });
        let record = RunRecord {
            label: label.to_string(),
            model: cfg.model.clone(),
            algo: algo.name(),
            replicas: n_workers,
            curve,
            wall_s,
            final_val_err: last.val_err,
            final_train_err: last.train_err,
            final_train_loss: last.train_loss,
            comm_bytes: comm_offset + meter.bytes(),
            comm_ratio: if step_seconds > 0.0 {
                comm_s / step_seconds
            } else {
                f64::NAN
            },
            phases: profiler.snapshot(),
        };
        Ok(TrainOutput {
            record,
            final_params: algo.into_params(),
        })
    }
}

/// Per-worker training sets: disjoint shards under `cfg.split_data`
/// (when the strategy shards at all), otherwise the shared set. A pure
/// function of (config, worker count), so a remote worker process
/// rebuilds exactly the shard the in-process engine would have handed
/// its slot — the data half of the cross-transport determinism
/// guarantee.
pub fn shard_datasets(
    cfg: &RunConfig,
    shards_data: bool,
    train_ds: Dataset,
    n_workers: usize,
) -> Result<Vec<Arc<Dataset>>> {
    if cfg.split_data && shards_data {
        match &train_ds {
            Dataset::Image(img) => Ok(split_shards(img, n_workers, cfg.seed)
                .into_iter()
                .map(|s| Arc::new(Dataset::Image(s)))
                .collect()),
            Dataset::Corpus(_) => {
                bail!("split_data needs an image dataset")
            }
        }
    } else {
        let shared = Arc::new(train_ds);
        Ok((0..n_workers).map(|_| shared.clone()).collect())
    }
}

/// Run one replica leg of `algo` against a remote master over TCP: the
/// `--role worker` side of a distributed run. Connects to `connect`
/// (retrying while the master is still binding), learns its replica
/// slot from the handshake, rebuilds its data shard locally from the
/// shared config, and drives the exact worker body the in-process
/// engine would have spawned as a thread. Returns when the master
/// sends `Stop` or hangs up.
///
/// The config must match the master's run (model, algorithm, seed,
/// replicas, hyperparameters): the master never ships config over the
/// wire, it ships rounds — a mismatched worker silently computes the
/// wrong trajectory, which is why the handshake at least cross-checks
/// the world size.
pub fn serve_worker_as(
    algo: &dyn RoundAlgo,
    cfg: &RunConfig,
    connect: &str,
) -> Result<()> {
    let session = Session::open(&cfg.artifacts_dir)?;
    let mm = session.manifest.model(&cfg.model)?.clone();
    drop(session); // the worker body opens its own session
    let (train_ds, _val) = build(&mm.dataset, &cfg.data)?;
    let augment = default_augment(&mm.dataset);
    let n_workers = algo.groups().len();
    let datasets =
        shard_datasets(cfg, algo.shards_data(), train_ds, n_workers)?;
    let link = TcpWorkerLink::connect_with_opts(
        connect,
        n_workers,
        std::time::Duration::from_secs(30),
        TcpConnectOpts {
            codec: cfg.wire_codec,
            fingerprint: Some(cfg.replay_fingerprint()),
            heartbeat_every: std::time::Duration::from_secs_f64(
                cfg.heartbeat_secs,
            ),
            master_silence: std::time::Duration::from_secs_f64(
                cfg.master_silence_secs,
            ),
        },
    )?;
    let id = link.replica();
    info!("worker {id}/{n_workers} serving rounds from {connect}");
    let body = algo.worker_body(id, &datasets, augment);
    body(ReplicaEndpoint::remote(link))
}

/// Total communication rounds for a run (pre-refactor formula, shared
/// by every strategy): `ceil(epochs * B / steps_per_round)`, at least 1.
pub fn total_rounds(epochs: f64, batches_per_epoch: usize,
                    steps_per_round: f64) -> u64 {
    ((epochs * batches_per_epoch as f64 / steps_per_round).ceil() as u64)
        .max(1)
}

/// Whether round `round` (0-based) is on the eval cadence (the final
/// round always evaluates, handled separately).
pub fn eval_due(round: u64, eval_every: u64) -> bool {
    eval_every > 0 && (round + 1) % eval_every == 0
}

/// Destination for the checkpoint written after round `round` (1-based):
/// `cfg.checkpoint_path` with any `{round}` placeholder substituted, or
/// `checkpoints/<label>.ck` when unset.
pub fn checkpoint_path(cfg: &RunConfig, label: &str, round: u64) -> String {
    let base = cfg.checkpoint_path.clone().unwrap_or_else(|| {
        format!("checkpoints/{}.ck", label.replace('/', "_"))
    });
    base.replace("{round}", &round.to_string())
}

/// Auxiliary master vector `master.<name>` from a checkpoint (the
/// counterpart of [`RoundAlgo::state_vecs`] for
/// [`RoundAlgo::restore_state`] implementations).
pub fn master_vec<'c>(ck: &'c Checkpoint, name: &str) -> Result<&'c [f32]> {
    ck.vec_f32(&format!("master.{name}")).ok_or_else(|| {
        anyhow::anyhow!("checkpoint missing master vector {name:?}")
    })
}

/// Snapshot of the run's accumulated totals for a checkpoint write.
struct CkState<'a> {
    next_round: u64,
    /// Per-replica completed-round stamps (`w<id>.rounds_done`): all
    /// equal to `next_round` at a synchronous barrier, per-replica in
    /// async mode so each replica resumes at its own round.
    rounds_done: &'a [u64],
    scoping_rounds: u64,
    wall_s: f64,
    step_seconds: f64,
    comm_bytes: u64,
    last_train: (f64, f64),
    curve: &'a Curve,
    phases: std::collections::BTreeMap<String, (f64, u64)>,
}

/// The scoping schedule's values at an arbitrary round index. The async
/// loop dispatches replicas sitting on different rounds, so the annealed
/// constants are computed per dispatch; the schedule is a pure function
/// of its round counter, so a counter override reproduces it exactly.
fn scoping_at(base: &Scoping, round: u64) -> Scoping {
    let mut s = base.clone();
    s.set_rounds(round);
    s
}

/// Mean of the finite entries (per-replica stats where a replica may
/// not have reported yet); NaN when none are finite.
fn mean_finite(v: &[f64]) -> f64 {
    let (sum, n) = v
        .iter()
        .filter(|x| x.is_finite())
        .fold((0.0f64, 0u64), |(s, n), x| (s + x, n + 1));
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

/// Per-replica completed-round stamps from a checkpoint's
/// `w<id>.rounds_done` meta keys. Absent keys (checkpoints written
/// before the async fabric) fall back to the global round — those were
/// written at a synchronous barrier where every replica sat on the same
/// round.
fn unpack_worker_rounds(ck: &Checkpoint, n_workers: usize, round: u64)
                        -> Result<Vec<u64>> {
    (0..n_workers)
        .map(|w| {
            let r = ck
                .meta_value(&format!("w{w}.rounds_done"))
                .map(|v| v as u64)
                .unwrap_or(round);
            if r < round {
                bail!(
                    "checkpoint worker {w} rounds_done {r} is below the \
                     global round {round}"
                );
            }
            Ok(r)
        })
        .collect()
}

/// Merge checkpointed phase totals back into the profiler (resume):
/// keys are `phase.<name>.s` / `phase.<name>.n` meta pairs.
fn restore_phases(profiler: &PhaseProfiler, ck: &Checkpoint) {
    for (k, v) in &ck.meta {
        if let Some(name) = k
            .strip_prefix("phase.")
            .and_then(|rest| rest.strip_suffix(".s"))
        {
            let calls = ck
                .meta_value(&format!("phase.{name}.n"))
                .unwrap_or(0.0) as u64;
            profiler.add_many(name, *v, calls);
        }
    }
}

fn write_checkpoint<A: RoundAlgo>(
    path: &str,
    cfg: &RunConfig,
    algo: &A,
    fabric: &mut ReduceFabric,
    st: CkState,
) -> Result<()> {
    let states = fabric.snapshot_workers()?;
    // elastic fabrics snapshot only the live members, so the state
    // count may trail the per-replica round stamps
    debug_assert!(states.len() <= st.rounds_done.len());
    let fp = cfg.replay_fingerprint();
    let mut ck = Checkpoint::new(&cfg.model, algo.params().to_vec())
        .with("round", st.next_round as f64)
        .with("scoping_rounds", st.scoping_rounds as f64)
        .with("wall_s", st.wall_s)
        .with("step_seconds", st.step_seconds)
        .with("comm_bytes", st.comm_bytes as f64)
        .with("train_loss", st.last_train.0)
        .with("train_err", st.last_train.1)
        .with("workers", states.len() as f64)
        // resume-compatibility stamp: u64 seed split into exact f64
        // halves, the round structure, and the algorithm tag
        .with("seed_lo", (cfg.seed & 0xffff_ffff) as f64)
        .with("seed_hi", (cfg.seed >> 32) as f64)
        .with("l_steps", cfg.l_steps as f64)
        .with("cfg_lo", (fp & 0xffff_ffff) as f64)
        .with("cfg_hi", (fp >> 32) as f64)
        .with_vec_f32(&format!("algo.{}", algo.name()), Vec::new())
        .with_vec_f64("curve", curve_to_f64(st.curve));
    for (name, (s, n)) in &st.phases {
        ck = ck
            .with(&format!("phase.{name}.s"), *s)
            .with(&format!("phase.{name}.n"), *n as f64);
    }
    for (name, v) in algo.state_vecs() {
        ck = ck.with_vec_f32(&format!("master.{name}"), v);
    }
    for ws in states {
        ck = ck
            .with(
                &format!("w{}.batches_drawn", ws.replica),
                ws.batches_drawn as f64,
            )
            .with(
                &format!("w{}.rounds_done", ws.replica),
                st.rounds_done[ws.replica] as f64,
            );
        for (name, v) in ws.vecs {
            ck = ck.with_vec_f32(&format!("w{}.{}", ws.replica, name), v);
        }
    }
    ck.save_atomic(path)
        .with_context(|| format!("writing checkpoint {path}"))
}

/// Rebuild every worker's [`WorkerState`] from the `w<id>.*` checkpoint
/// sections. Vector lengths are validated against the model's param
/// count here, on the master, so a mangled checkpoint fails fast with
/// the real cause instead of killing a worker thread mid-restore (whose
/// error would only surface as "replica died mid-round" at the next
/// collect). Every current strategy persists only P-sized worker
/// vectors; a future strategy with differently-sized worker state
/// should move this invariant into the trait (e.g. a
/// `worker_vec_len(name)` hook) rather than delete the check.
fn unpack_worker_states(ck: &Checkpoint, n_workers: usize, p: usize)
                        -> Result<Vec<WorkerState>> {
    (0..n_workers)
        .map(|w| {
            let prefix = format!("w{w}.");
            let vecs: Vec<(String, Vec<f32>)> = ck
                .vecs_f32
                .iter()
                .filter(|(k, _)| k.starts_with(&prefix))
                .map(|(k, v)| (k[prefix.len()..].to_string(), v.clone()))
                .collect();
            for (name, v) in &vecs {
                if v.len() != p {
                    bail!(
                        "checkpoint worker vector w{w}.{name} has {} \
                         params, model has {p}",
                        v.len()
                    );
                }
            }
            let batches_drawn =
                ck.require_meta(&format!("w{w}.batches_drawn"))? as u64;
            Ok(WorkerState {
                replica: w,
                vecs,
                batches_drawn,
            })
        })
        .collect()
}

fn curve_to_f64(curve: &Curve) -> Vec<f64> {
    curve
        .points
        .iter()
        .flat_map(|p| {
            [p.wall_s, p.epoch, p.train_loss, p.train_err, p.val_err]
        })
        .collect()
}

fn curve_from_f64(v: &[f64]) -> Result<Curve> {
    if v.len() % 5 != 0 {
        bail!("corrupt checkpoint curve: {} values", v.len());
    }
    let mut curve = Curve::new();
    for c in v.chunks_exact(5) {
        curve.push(CurvePoint {
            wall_s: c[0],
            epoch: c[1],
            train_loss: c[2],
            train_err: c[3],
            val_err: c[4],
        });
    }
    Ok(curve)
}

// ---------------------------------------------------------------------------
// evaluation
// ---------------------------------------------------------------------------

/// Metadata of an in-flight evaluation: everything the curve point and
/// the log line need besides the val error itself.
struct Pending {
    round: u64,
    total_rounds: u64,
    lr: f32,
    /// Scoping values after this round's anneal step (what the legacy
    /// coupled driver logged).
    gamma: f32,
    rho: f32,
    epoch: f64,
    train_loss: f64,
    train_err: f64,
}

enum EvalMode {
    /// Evaluate on the master thread (inside the round barrier).
    Inline {
        session: Session,
        model: String,
        mm: ModelManifest,
        batches: Vec<Batch>,
    },
    /// Dedicated eval thread + session; sweeps overlap the next round.
    /// Results arrive as `(val_err, wall_s at sweep completion)` so the
    /// curve point carries the time the evaluation actually finished,
    /// not the (up to one eval interval later) harvest time.
    Overlap {
        req_tx: mpsc::Sender<Vec<f32>>,
        res_rx: mpsc::Receiver<(f64, f64)>,
        handle: Option<JoinHandle<Result<()>>>,
    },
}

struct Evaluator {
    mode: EvalMode,
    pending: Option<Pending>,
    profiler: Arc<PhaseProfiler>,
}

impl Evaluator {
    fn inline(
        session: Session,
        model: String,
        mm: ModelManifest,
        batches: Vec<Batch>,
        profiler: Arc<PhaseProfiler>,
    ) -> Self {
        Evaluator {
            mode: EvalMode::Inline {
                session,
                model,
                mm,
                batches,
            },
            pending: None,
            profiler,
        }
    }

    fn overlapped(
        cfg: &RunConfig,
        batches: Vec<Batch>,
        profiler: Arc<PhaseProfiler>,
        wall_start: std::time::Instant,
        wall_offset: f64,
    ) -> Self {
        let (req_tx, req_rx) = mpsc::channel::<Vec<f32>>();
        let (res_tx, res_rx) = mpsc::channel::<(f64, f64)>();
        let dir = cfg.artifacts_dir.clone();
        let model = cfg.model.clone();
        let prof = profiler.clone();
        // PJRT sessions are not Send: the thread opens its own.
        let handle = std::thread::spawn(move || -> Result<()> {
            let session =
                Session::open(&dir).context("eval thread session")?;
            let mm = session.manifest.model(&model)?.clone();
            while let Ok(params) = req_rx.recv() {
                let t = Timer::new();
                let val = evaluate(&session, &model, &mm, &params, &batches)?;
                prof.add("eval", t.elapsed_s());
                let wall_s =
                    wall_offset + wall_start.elapsed().as_secs_f64();
                if res_tx.send((val, wall_s)).is_err() {
                    break;
                }
            }
            Ok(())
        });
        Evaluator {
            mode: EvalMode::Overlap {
                req_tx,
                res_rx,
                handle: Some(handle),
            },
            pending: None,
            profiler,
        }
    }

    /// Evaluate `params` for the round described by `p`. Inline mode
    /// blocks and pushes the curve point now; overlapped mode first
    /// harvests any still-pending sweep (that wait is the `eval_exposed`
    /// phase), then dispatches this one and returns immediately.
    fn request(
        &mut self,
        params: &[f32],
        p: Pending,
        curve: &mut Curve,
        wall: &Timer,
        wall_offset: f64,
        label: &str,
    ) -> Result<()> {
        match &mut self.mode {
            EvalMode::Inline {
                session,
                model,
                mm,
                batches,
            } => {
                let val = self.profiler.scope("eval", || {
                    evaluate(session, model, mm, params, batches)
                })?;
                push_point(curve, &p, val, wall_offset + wall.elapsed_s(),
                           label);
            }
            EvalMode::Overlap {
                req_tx,
                res_rx,
                handle,
            } => {
                if let Some(prev) = self.pending.take() {
                    harvest(&self.profiler, res_rx, handle, prev, curve,
                            label)?;
                }
                if req_tx.send(params.to_vec()).is_err() {
                    return Err(eval_thread_error(handle));
                }
                self.pending = Some(p);
            }
        }
        Ok(())
    }

    /// Block until no evaluation is in flight, pushing its curve point
    /// (stamped with the sweep's completion time).
    fn drain(&mut self, curve: &mut Curve, label: &str) -> Result<()> {
        if let Some(prev) = self.pending.take() {
            if let EvalMode::Overlap {
                res_rx, handle, ..
            } = &mut self.mode
            {
                harvest(&self.profiler, res_rx, handle, prev, curve,
                        label)?;
            }
        }
        Ok(())
    }

    /// Stop the eval thread (if any) and surface its error, if it died.
    fn shutdown(self) -> Result<()> {
        if let EvalMode::Overlap {
            req_tx,
            handle,
            ..
        } = self.mode
        {
            drop(req_tx);
            if let Some(h) = handle {
                match h.join() {
                    Ok(r) => r?,
                    Err(_) => bail!("eval thread panicked"),
                }
            }
        }
        Ok(())
    }
}

/// Receive one pending sweep's result — the exposed wait the profiler
/// charges to `eval_exposed` — and push its curve point, stamped with
/// the sweep's completion time. Shared by round-time harvests
/// ([`Evaluator::request`]) and checkpoint/shutdown drains
/// ([`Evaluator::drain`]) so the two paths cannot diverge.
fn harvest(
    profiler: &PhaseProfiler,
    res_rx: &mpsc::Receiver<(f64, f64)>,
    handle: &mut Option<JoinHandle<Result<()>>>,
    prev: Pending,
    curve: &mut Curve,
    label: &str,
) -> Result<()> {
    let (val, at) = match profiler.scope("eval_exposed", || res_rx.recv())
    {
        Ok(v) => v,
        Err(_) => return Err(eval_thread_error(handle)),
    };
    push_point(curve, &prev, val, at, label);
    Ok(())
}

/// The eval thread hung up mid-run: join it so the error the user sees
/// is the thread's root cause (artifact failure, session error), not a
/// bare closed-channel message.
fn eval_thread_error(handle: &mut Option<JoinHandle<Result<()>>>)
                     -> anyhow::Error {
    match handle.take() {
        Some(h) => match h.join() {
            Ok(Ok(())) => {
                anyhow::anyhow!("eval thread exited unexpectedly")
            }
            Ok(Err(e)) => e.context("eval thread failed"),
            Err(_) => anyhow::anyhow!("eval thread panicked"),
        },
        None => anyhow::anyhow!("eval thread died"),
    }
}

fn push_point(curve: &mut Curve, p: &Pending, val_err: f64, wall_s: f64,
              label: &str) {
    curve.push(CurvePoint {
        wall_s,
        epoch: p.epoch,
        train_loss: p.train_loss,
        train_err: p.train_err,
        val_err,
    });
    info!(
        "{label} round {}/{} epoch {:.2} lr {:.4} γ {:.2} ρ {:.3} \
         train {:.3}/{:.1}% val {:.2}%",
        p.round + 1,
        p.total_rounds,
        p.epoch,
        p.lr,
        p.gamma,
        p.rho,
        p.train_loss,
        p.train_err * 100.0,
        val_err * 100.0
    );
}

// ---------------------------------------------------------------------------
// shared helpers (used by every strategy; re-exported through driver.rs)
// ---------------------------------------------------------------------------

/// Batches per epoch under GLOBAL-dataset semantics: one epoch is one
/// pass of the *whole* training set through the ensemble. Sharding (§5,
/// `split_data`) divides the data between replicas but must not shrink
/// the epoch — computing this from a shard's length would cut scoping's
/// B and `total_rounds` by the replica count versus unsharded runs.
pub fn epoch_batches(global_train_len: usize, batch: usize) -> usize {
    (global_train_len / batch.max(1)).max(1)
}

/// Mean validation error of `params` over pre-built eval batches.
///
/// `params` — the P-sized vector, identical for every batch — is
/// uploaded to the device exactly once per sweep; only the per-batch
/// inputs cross the host boundary afterwards. (The old literal path
/// re-marshalled all P floats on every batch.) Shared by every strategy
/// and by the engine's eval thread.
pub fn evaluate(
    session: &Session,
    model: &str,
    mm: &ModelManifest,
    params: &[f32],
    batches: &[Batch],
) -> Result<f64> {
    let p = mm.param_count;
    let params_buf = session.upload(&lit_f32(params, &[p])?)?;
    let mut err_count = 0.0f64;
    let mut total = 0.0f64;
    for b in batches {
        let (xb, yb) = crate::coordinator::replica::batch_literals(mm, b)?;
        let xb_buf = session.upload(&xb)?;
        let yb_buf = session.upload(&yb)?;
        let outs = session.execute_buffers(
            model,
            "eval_chunk",
            &[&params_buf, &xb_buf, &yb_buf],
        )?;
        let err = outs.get(1).ok_or_else(|| {
            anyhow::anyhow!("eval_chunk: missing error output")
        })?;
        err_count +=
            crate::runtime::scalar_f32(&session.download(err)?)? as f64;
        total += (b.n * mm.labels_per_example()) as f64;
    }
    Ok(err_count / total.max(1.0))
}

/// Augmentation policy per dataset tag (paper §4.2-§4.4: CIFAR gets
/// flips+crops, MNIST and SVHN are raw).
pub fn default_augment(dataset: &str) -> Augment {
    match dataset {
        "synth_cifar10" | "synth_cifar100" => Augment::cifar(),
        _ => Augment::none(),
    }
}

/// Sequence length for LM models (0 for image models).
pub fn lm_seq_len(mm: &ModelManifest) -> usize {
    if mm.label_shape.is_empty() {
        0
    } else {
        mm.input_shape[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pins the `split_data` epoch semantics: B comes from the global
    /// dataset, so sharding (which divides examples between replicas)
    /// leaves scoping's B and `total_rounds` identical to unsharded
    /// runs. Computing from a shard's length (the old behavior) would
    /// shrink both by the replica count.
    #[test]
    fn epoch_batches_uses_the_global_dataset() {
        let (global_len, batch, replicas) = (1000, 10, 4);
        assert_eq!(epoch_batches(global_len, batch), 100);
        let shard_len = global_len / replicas;
        assert_eq!(epoch_batches(shard_len, batch), 25);
        // degenerate guards
        assert_eq!(epoch_batches(0, batch), 1);
        assert_eq!(epoch_batches(7, 0), 7);
    }

    #[test]
    fn augment_policy() {
        assert!(default_augment("synth_cifar10").mirror);
        assert!(!default_augment("synth_mnist").mirror);
        assert_eq!(default_augment("synth_svhn").crop_pad, 0);
    }

    /// The round/eval accounting the three pre-refactor drivers each
    /// computed by hand, pinned to their exact values.
    #[test]
    fn round_and_eval_cadence_match_the_legacy_drivers() {
        // coupled: ceil(epochs * B / L)
        assert_eq!(total_rounds(6.0, 8, 2.0), 24);
        // data-parallel: one round per aggregate minibatch
        assert_eq!(total_rounds(6.0, 8, 1.0), 48);
        // fractional epochs round up; floor at one round
        assert_eq!(total_rounds(0.5, 8, 25.0), 1);
        assert_eq!(total_rounds(0.0, 8, 1.0), 1);
        // eval every 4 rounds fires at rounds 3, 7, ... (0-based)
        assert!(!eval_due(2, 4));
        assert!(eval_due(3, 4));
        assert!(!eval_due(4, 4));
        // 0 disables the cadence entirely
        assert!(!eval_due(3, 0));
    }

    #[test]
    fn checkpoint_path_templating() {
        let mut cfg = RunConfig::new("mlp_synth", crate::config::Algo::Parle);
        assert_eq!(
            checkpoint_path(&cfg, "a/b", 7),
            "checkpoints/a_b.ck"
        );
        cfg.checkpoint_path = Some("out/ck_{round}.ck".into());
        assert_eq!(checkpoint_path(&cfg, "x", 12), "out/ck_12.ck");
    }

    #[test]
    fn curve_f64_roundtrip_is_bit_exact() {
        let mut c = Curve::new();
        for i in 0..3 {
            c.push(CurvePoint {
                wall_s: i as f64 + 0.125,
                epoch: i as f64 * 0.5,
                train_loss: 1.0 / (i + 1) as f64,
                train_err: f64::NAN,
                val_err: 0.25,
            });
        }
        let back = curve_from_f64(&curve_to_f64(&c)).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in c.points.iter().zip(&back.points) {
            assert_eq!(a.wall_s.to_bits(), b.wall_s.to_bits());
            assert_eq!(a.train_err.to_bits(), b.train_err.to_bits());
        }
        assert!(curve_from_f64(&[1.0, 2.0]).is_err());
    }

    /// Resumed records must report whole-run phase accounting: the
    /// checkpointed totals merge into the fresh profiler, so comm_ratio
    /// (reduce seconds / step seconds, both cumulative) stays honest.
    #[test]
    fn checkpointed_phase_totals_merge_on_resume() {
        let ck = Checkpoint::new("m", vec![])
            .with("phase.reduce.s", 12.5)
            .with("phase.reduce.n", 100.0)
            .with("phase.eval.s", 3.0)
            .with("phase.eval.n", 10.0)
            .with("unrelated", 1.0);
        let profiler = PhaseProfiler::new();
        profiler.add("reduce", 0.5);
        restore_phases(&profiler, &ck);
        assert_eq!(profiler.snapshot()["reduce"], (13.0, 101));
        assert_eq!(profiler.snapshot()["eval"], (3.0, 10));
        assert!(!profiler.snapshot().contains_key("unrelated"));
    }

    #[test]
    fn scoping_at_reproduces_the_schedule_at_any_round() {
        let mut base = Scoping::paper(50);
        for _ in 0..10 {
            base.step();
        }
        // values at round 37 are identical whether stepped to or jumped
        // to — the async loop relies on this for per-dispatch constants
        let mut stepped = Scoping::paper(50);
        for _ in 0..37 {
            stepped.step();
        }
        let jumped = scoping_at(&base, 37);
        assert_eq!(jumped.gamma().to_bits(), stepped.gamma().to_bits());
        assert_eq!(jumped.rho().to_bits(), stepped.rho().to_bits());
        // and the base schedule is untouched
        assert_eq!(base.rounds(), 10);
    }

    #[test]
    fn mean_finite_skips_unreported_replicas() {
        assert_eq!(mean_finite(&[1.0, f64::NAN, 3.0]), 2.0);
        assert_eq!(mean_finite(&[4.0]), 4.0);
        assert!(mean_finite(&[f64::NAN, f64::NAN]).is_nan());
        assert!(mean_finite(&[]).is_nan());
    }

    /// Per-replica round stamps round-trip through the checkpoint meta
    /// layout; checkpoints without them (pre-async) fall back to the
    /// global round, and stamps below it are rejected.
    #[test]
    fn worker_rounds_unpack_and_fallback() {
        let ck = Checkpoint::new("m", vec![])
            .with("w0.rounds_done", 7.0)
            .with("w1.rounds_done", 5.0);
        assert_eq!(unpack_worker_rounds(&ck, 2, 5).unwrap(), vec![7, 5]);
        // a third worker without a stamp falls back to the global round
        assert_eq!(
            unpack_worker_rounds(&ck, 3, 5).unwrap(),
            vec![7, 5, 5]
        );
        // a stamp below the global round is corrupt
        assert!(unpack_worker_rounds(&ck, 2, 6).is_err());
    }

    /// Worker states written by `write_checkpoint`'s key layout come
    /// back intact, including at double-digit worker ids (w1 must not
    /// swallow w12's sections).
    #[test]
    fn worker_state_pack_unpack_roundtrip() {
        let n = 13;
        let mut ck = Checkpoint::new("m", vec![]).with("workers", n as f64);
        for w in 0..n {
            ck = ck.with(&format!("w{w}.batches_drawn"), (w * 10) as f64);
            ck = ck
                .with_vec_f32(&format!("w{w}.y"), vec![w as f32; 3])
                .with_vec_f32(&format!("w{w}.mom"), vec![-(w as f32); 3]);
        }
        let states = unpack_worker_states(&ck, n, 3).unwrap();
        assert_eq!(states.len(), n);
        for (w, st) in states.iter().enumerate() {
            assert_eq!(st.replica, w);
            assert_eq!(st.batches_drawn, (w * 10) as u64);
            assert_eq!(st.vecs.len(), 2, "worker {w}");
            assert_eq!(st.vec("y"), Some(&[w as f32; 3][..]));
            assert_eq!(st.vec("mom"), Some(&[-(w as f32); 3][..]));
        }
        // a missing worker errors instead of silently resuming
        assert!(unpack_worker_states(&ck, n + 1, 3).is_err());
        // a length-mismatched vector fails fast on the master with the
        // real cause, not inside a worker thread
        let err = unpack_worker_states(&ck, n, 4).unwrap_err().to_string();
        assert!(err.contains("w0.y has 3 params"), "{err}");
    }
}

//! Host <-> `xla::Literal` conversion helpers and the host<->device
//! [`TransferMeter`].
//!
//! The meter mirrors the fabric's `CommMeter` (coordinator/comm.rs): it
//! counts every byte that crosses the host<->device boundary so the
//! replica hot path can *prove* its traffic is O(P) per round instead of
//! O(P*L). Both `Session::upload`/`Session::download` and the
//! literal-marshalling `Session::execute` path account here, which makes
//! the two dispatch strategies directly comparable.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};
use xla::Literal;

/// f32 literal with an explicit shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let numel: usize = shape.iter().product();
    if numel != data.len() {
        bail!("lit_f32: {} elements vs shape {:?}", data.len(), shape);
    }
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// i32 literal with an explicit shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let numel: usize = shape.iter().product();
    if numel != data.len() {
        bail!("lit_i32: {} elements vs shape {:?}", data.len(), shape);
    }
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

pub fn lit_scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

pub fn lit_scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Extract an f32 literal to a host vector.
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32 (loss/error outputs).
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != 1 {
        bail!("expected scalar, got {} elements", v.len());
    }
    Ok(v[0])
}

/// Byte size of a (non-tuple) literal. Every dtype in the artifact
/// contract is 4 bytes wide (f32/i32 — see `artifact::DType`), so the
/// element count is enough.
pub fn lit_bytes(lit: &Literal) -> usize {
    lit.element_count() * 4
}

/// Counts every byte crossing the host<->device boundary, split by
/// direction. Shared by a `Session` and its callers via `Arc`; all
/// counters are relaxed atomics so worker threads can account without
/// coordination (exact totals are only read at rest, e.g. in tests and
/// bench reports).
#[derive(Default)]
pub struct TransferMeter {
    up_bytes: AtomicU64,
    down_bytes: AtomicU64,
    uploads: AtomicU64,
    downloads: AtomicU64,
}

impl TransferMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn account_upload(&self, bytes: usize) {
        self.up_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.uploads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn account_download(&self, bytes: usize) {
        self.down_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.downloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Host -> device bytes so far.
    pub fn upload_bytes(&self) -> u64 {
        self.up_bytes.load(Ordering::Relaxed)
    }

    /// Device -> host bytes so far.
    pub fn download_bytes(&self) -> u64 {
        self.down_bytes.load(Ordering::Relaxed)
    }

    /// Total bytes, both directions.
    pub fn bytes(&self) -> u64 {
        self.upload_bytes() + self.download_bytes()
    }

    pub fn uploads(&self) -> u64 {
        self.uploads.load(Ordering::Relaxed)
    }

    pub fn downloads(&self) -> u64 {
        self.downloads.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_f32(&lit).unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalars() {
        let s = lit_scalar_f32(2.5);
        assert_eq!(scalar_f32(&s).unwrap(), 2.5);
    }

    #[test]
    fn literal_byte_size() {
        let lit = lit_f32(&[0.0; 6], &[2, 3]).unwrap();
        assert_eq!(lit_bytes(&lit), 24);
        assert_eq!(lit_bytes(&lit_scalar_i32(1)), 4);
    }

    #[test]
    fn meter_accumulates_per_direction() {
        let m = TransferMeter::new();
        m.account_upload(100);
        m.account_upload(24);
        m.account_download(8);
        assert_eq!(m.upload_bytes(), 124);
        assert_eq!(m.download_bytes(), 8);
        assert_eq!(m.bytes(), 132);
        assert_eq!(m.uploads(), 2);
        assert_eq!(m.downloads(), 1);
    }
}

//! Host <-> `xla::Literal` conversion helpers.

use anyhow::{bail, Result};
use xla::Literal;

/// f32 literal with an explicit shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let numel: usize = shape.iter().product();
    if numel != data.len() {
        bail!("lit_f32: {} elements vs shape {:?}", data.len(), shape);
    }
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// i32 literal with an explicit shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let numel: usize = shape.iter().product();
    if numel != data.len() {
        bail!("lit_i32: {} elements vs shape {:?}", data.len(), shape);
    }
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

pub fn lit_scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

pub fn lit_scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Extract an f32 literal to a host vector.
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32 (loss/error outputs).
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != 1 {
        bail!("expected scalar, got {} elements", v.len());
    }
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_f32(&lit).unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalars() {
        let s = lit_scalar_f32(2.5);
        assert_eq!(scalar_f32(&s).unwrap(), 2.5);
    }
}

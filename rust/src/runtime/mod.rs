//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` + the
//! manifest) and executes them on the CPU PJRT client via the `xla` crate.
//!
//! The interchange format is HLO *text* — xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
//!
//! PJRT wrapper types hold raw pointers and are not `Send`: every worker
//! thread owns its own [`Session`] (client + compiled executables), which
//! mirrors a real one-device-per-replica deployment.
//!
//! Two execution paths (see `executor`): the literal path marshals host
//! vectors on every dispatch; the buffer path
//! (`Session::upload`/`execute_buffers`/`download`) keeps operands
//! device-resident between dispatches. The per-session [`TransferMeter`]
//! accounts every host<->device byte on both paths.

pub mod artifact;
pub mod executor;
pub mod round_driver;
pub mod tensor;

pub use artifact::{ArtifactSig, LayerInfo, Manifest, ModelManifest, TensorSig};
pub use executor::Session;
pub use tensor::{lit_bytes, lit_f32, lit_i32, lit_scalar_f32,
                 lit_scalar_i32, scalar_f32, to_f32, TransferMeter};

//! Session: one PJRT CPU client + a cache of compiled executables.
//!
//! One `Session` per worker thread (PJRT wrapper types are not `Send`).
//! Artifacts are compiled lazily on first use and cached for the life of
//! the session; `execute` validates input arity/shape against the
//! manifest before dispatch so shape bugs surface as errors, not XLA
//! aborts.
//!
//! # Two execution paths
//!
//! * **Literal path** ([`Session::execute`]) — host literals in, host
//!   literals out. Every call re-marshals all inputs to the device and
//!   fetches all outputs back; right for init, evaluation one-offs and
//!   tests.
//! * **Buffer path** ([`Session::upload`] / [`Session::execute_buffers`]
//!   / [`Session::download`]) — operands live in device-resident
//!   `PjRtBuffer`s; outputs come back as buffers that can feed the next
//!   dispatch directly. This is the replica inner loop's path: the state
//!   triple (y, z, mom) crosses the host boundary once per *round*, not
//!   once per step.
//!
//! Both paths account every host<->device byte on the session's
//! [`TransferMeter`], so the traffic asymmetry is measurable, not
//! assumed.
//!
//! # Validation contract
//!
//! The literal path validates input arity, shape and dtype against the
//! manifest before dispatch so shape bugs surface as errors, not XLA
//! aborts. The buffer path validates **arity only**: buffer contents
//! are device-side, so shape errors there surface from XLA itself —
//! callers construct their operands through `lit_f32`/`lit_i32` (which
//! reject length/shape mismatches at build time) before uploading.
//!
//! # Tupled vs untupled results
//!
//! AOT lowers with return_tuple=True. Depending on the runtime's
//! execute options the result arrives either as one buffer per output
//! leaf (untupled — the buffer path stays fully device-resident) or as
//! a single intact tuple-root buffer. Both paths handle both shapes;
//! in the tuple-root case [`Session::execute_buffers`] reconstructs
//! the leaves through an accounted host round-trip, which costs no
//! more than the literal path ever did but loses the O(P)-per-round
//! property. [`Session::device_residency`] reports which world the
//! session has observed so callers/tests can react.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient,
          PjRtLoadedExecutable, XlaComputation};

use super::artifact::{ArtifactSig, DType, Manifest};
use super::tensor::{lit_bytes, TransferMeter};

/// A per-thread runtime session.
pub struct Session {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<(String, String), PjRtLoadedExecutable>>,
    meter: Arc<TransferMeter>,
    /// Whether dispatches come back untupled (state can stay
    /// device-resident) or as intact tuple roots (every dispatch pays a
    /// host round-trip). Unset until the first dispatch that can tell
    /// resolves it; both execution paths read and feed this cache, so
    /// the ambiguous single-output probes run at most once per session.
    residency: Cell<Option<bool>>,
}

impl Session {
    /// Open the artifacts directory (compiles nothing yet).
    pub fn open(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        Self::with_manifest(manifest)
    }

    /// Open with an already-parsed manifest (tests).
    pub fn with_manifest(manifest: Manifest) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Session {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            meter: Arc::new(TransferMeter::new()),
            residency: Cell::new(None),
        })
    }

    /// The session's host<->device transfer meter.
    pub fn transfer_meter(&self) -> Arc<TransferMeter> {
        self.meter.clone()
    }

    /// `Some(true)` once a multi-output buffer dispatch has come back
    /// untupled (device-resident loops get their O(P)-per-round
    /// traffic), `Some(false)` once one has come back as a tuple root
    /// (each dispatch pays a host round-trip — no worse than the
    /// literal path, but not O(P)), `None` before either was observed.
    pub fn device_residency(&self) -> Option<bool> {
        self.residency.get()
    }

    /// Ensure `(model, step)` is compiled; returns nothing (warms cache).
    pub fn warm(&self, model: &str, step: &str) -> Result<()> {
        self.compiled(model, step).map(|_| ())
    }

    fn compiled(&self, model: &str, step: &str) -> Result<()> {
        let key = (model.to_string(), step.to_string());
        if self.cache.borrow().contains_key(&key) {
            return Ok(());
        }
        let mm = self.manifest.model(model)?;
        let art = mm.artifact(step)?;
        let path = self.manifest.dir.join(&art.file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().unwrap_or_default(),
        )
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {model}/{step}"))?;
        self.cache.borrow_mut().insert(key, exe);
        Ok(())
    }

    /// Copy a host literal into a device-resident buffer (accounted on
    /// the transfer meter).
    pub fn upload(&self, lit: &Literal) -> Result<PjRtBuffer> {
        let buf = self
            .client
            .buffer_from_host_literal(None, lit)
            .context("uploading literal to device")?;
        self.meter.account_upload(lit_bytes(lit));
        Ok(buf)
    }

    /// Copy a device-resident buffer back to a host literal (accounted
    /// on the transfer meter).
    pub fn download(&self, buf: &PjRtBuffer) -> Result<Literal> {
        let lit = buf
            .to_literal_sync()
            .context("downloading device buffer to host")?;
        self.meter.account_download(lit_bytes(&lit));
        Ok(lit)
    }

    /// Execute `(model, step)` with the given inputs; returns the
    /// untupled outputs as host literals. Marshals every input up and
    /// every output down on each call — use the buffer path for loops.
    pub fn execute(
        &self,
        model: &str,
        step: &str,
        inputs: &[Literal],
    ) -> Result<Vec<Literal>> {
        let mm = self.manifest.model(model)?;
        let art = mm.artifact(step)?;
        validate_inputs(model, step, art, inputs)?;
        for lit in inputs {
            self.meter.account_upload(lit_bytes(lit));
        }
        self.compiled(model, step)?;
        let cache = self.cache.borrow();
        let exe = cache
            .get(&(model.to_string(), step.to_string()))
            .expect("compiled() populated the cache");
        let mut per_device = exe.execute::<Literal>(inputs)?;
        if per_device.is_empty() {
            bail!("{model}/{step}: executable returned no per-device results");
        }
        let bufs = per_device.swap_remove(0);
        let outs = match bufs.len() {
            0 => bail!("{model}/{step}: executable yielded no result buffers"),
            // Ambiguous single-output case: the one buffer is either an
            // intact 1-tuple root or the untupled leaf itself. Resolve
            // from the session's cached residency answer; probe (and
            // cache) only while it is still unknown. `to_tuple` consumes
            // the literal, so a failed probe costs one extra download —
            // but at most once per session now, not once per call, and
            // every transfer lands on the meter.
            1 if art.outputs.len() == 1 => {
                let lit = bufs[0].to_literal_sync().with_context(|| {
                    format!("fetching result of {model}/{step}")
                })?;
                match self.residency.get() {
                    Some(true) => {
                        self.meter.account_download(lit_bytes(&lit));
                        vec![lit]
                    }
                    Some(false) => {
                        let leaves = lit.to_tuple()?;
                        if leaves.len() != 1 {
                            return Err(arity1_violation(
                                model,
                                step,
                                leaves.len(),
                            ));
                        }
                        self.meter.account_download(lit_bytes(&leaves[0]));
                        leaves
                    }
                    None => match lit.to_tuple() {
                        Ok(leaves) if leaves.len() == 1 => {
                            self.residency.set(Some(false));
                            self.meter
                                .account_download(lit_bytes(&leaves[0]));
                            leaves
                        }
                        Ok(leaves) => {
                            return Err(arity1_violation(
                                model,
                                step,
                                leaves.len(),
                            ))
                        }
                        Err(_) => {
                            // not a tuple: the buffer IS the leaf, but
                            // the probe consumed the literal — re-fetch
                            // once (cached afterwards) and account both
                            // transfers the probe cost
                            self.residency.set(Some(true));
                            let lit = bufs[0]
                                .to_literal_sync()
                                .with_context(|| {
                                    format!(
                                        "fetching result of {model}/{step}"
                                    )
                                })?;
                            self.meter
                                .account_download(2 * lit_bytes(&lit));
                            vec![lit]
                        }
                    },
                }
            }
            // AOT lowers with return_tuple=True: when the runtime hands
            // the tuple root back as one buffer, untuple on the host.
            1 => {
                self.residency.set(Some(false));
                let leaves = bufs[0]
                    .to_literal_sync()
                    .with_context(|| {
                        format!("fetching result of {model}/{step}")
                    })?
                    .to_tuple()?;
                for l in &leaves {
                    self.meter.account_download(lit_bytes(l));
                }
                leaves
            }
            // Runtimes that untuple on execute hand back one buffer per
            // output leaf; fetch each.
            _ => {
                self.residency.set(Some(true));
                bufs.iter()
                    .map(|b| self.download(b))
                    .collect::<Result<Vec<_>>>()?
            }
        };
        if outs.len() != art.outputs.len() {
            bail!(
                "{model}/{step}: manifest promises {} outputs, got {}",
                art.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Execute `(model, step)` with device-resident inputs, returning
    /// one device-resident buffer per manifest output. State threaded
    /// through consecutive dispatches never crosses the host boundary.
    ///
    /// If the runtime returns the un-split tuple root as a single buffer
    /// (instead of one buffer per output leaf), the leaves are
    /// reconstructed through a host round-trip — correct, but at
    /// literal-path transfer cost, and visibly so on the meter.
    pub fn execute_buffers(
        &self,
        model: &str,
        step: &str,
        inputs: &[&PjRtBuffer],
    ) -> Result<Vec<PjRtBuffer>> {
        let mm = self.manifest.model(model)?;
        let art = mm.artifact(step)?;
        if inputs.len() != art.inputs.len() {
            bail!(
                "{model}/{step}: expected {} inputs, got {}",
                art.inputs.len(),
                inputs.len()
            );
        }
        self.compiled(model, step)?;
        let cache = self.cache.borrow();
        let exe = cache
            .get(&(model.to_string(), step.to_string()))
            .expect("compiled() populated the cache");
        let mut per_device = exe.execute_b(inputs)?;
        if per_device.is_empty() {
            bail!("{model}/{step}: executable returned no per-device results");
        }
        let bufs = per_device.swap_remove(0);
        let arity = art.outputs.len();
        match (bufs.len(), arity) {
            (0, _) => {
                bail!("{model}/{step}: executable yielded no result buffers")
            }
            (n, a) if n == a && n > 1 => {
                self.residency.set(Some(true));
                Ok(bufs)
            }
            // Ambiguous single-output case: either the untupled leaf or
            // an intact 1-tuple root. Resolve from what this session
            // has already learned; probe (one accounted host download)
            // only while residency is still unknown.
            (1, 1) => match self.residency.get() {
                Some(true) => Ok(bufs),
                Some(false) => {
                    let leaves = bufs[0].to_literal_sync()?.to_tuple()?;
                    if leaves.len() != 1 {
                        return Err(arity1_violation(model, step,
                                                    leaves.len()));
                    }
                    self.meter.account_download(lit_bytes(&leaves[0]));
                    Ok(vec![self.upload(&leaves[0])?])
                }
                None => match bufs[0].to_literal_sync()?.to_tuple() {
                    Ok(leaves) if leaves.len() == 1 => {
                        self.residency.set(Some(false));
                        self.meter.account_download(lit_bytes(&leaves[0]));
                        Ok(vec![self.upload(&leaves[0])?])
                    }
                    // A multi-leaf tuple root under an arity-1 manifest
                    // is a contract violation: error out instead of
                    // classifying it as an untupled leaf (which would
                    // poison the residency cache for every later
                    // dispatch on this session).
                    Ok(leaves) => {
                        Err(arity1_violation(model, step, leaves.len()))
                    }
                    Err(_) => {
                        // not a tuple: the buffer is the untupled leaf
                        self.residency.set(Some(true));
                        // the probe still moved the payload down once
                        self.meter
                            .account_download(art.outputs[0].numel() * 4);
                        Ok(bufs)
                    }
                },
            },
            (1, _) => {
                // tuple root intact: untuple via the host and re-upload
                self.residency.set(Some(false));
                let leaves = bufs[0]
                    .to_literal_sync()
                    .with_context(|| {
                        format!("fetching tupled result of {model}/{step}")
                    })?
                    .to_tuple()?;
                if leaves.len() != arity {
                    bail!(
                        "{model}/{step}: manifest promises {arity} outputs, \
                         tuple has {}",
                        leaves.len()
                    );
                }
                for l in &leaves {
                    self.meter.account_download(lit_bytes(l));
                }
                leaves.iter().map(|l| self.upload(l)).collect()
            }
            (n, _) => bail!(
                "{model}/{step}: manifest promises {arity} outputs, \
                 runtime produced {n} buffers"
            ),
        }
    }
}

/// Contract violation shared by the ambiguous single-output probe
/// branches of both execution paths: the manifest promises exactly one
/// output but the runtime's tuple root carries a different leaf count.
fn arity1_violation(model: &str, step: &str, got: usize) -> anyhow::Error {
    anyhow::anyhow!(
        "{model}/{step}: manifest promises 1 output, tuple has {got}"
    )
}

fn validate_inputs(
    model: &str,
    step: &str,
    art: &ArtifactSig,
    inputs: &[Literal],
) -> Result<()> {
    if inputs.len() != art.inputs.len() {
        bail!(
            "{model}/{step}: expected {} inputs, got {}",
            art.inputs.len(),
            inputs.len()
        );
    }
    for (i, (sig, lit)) in art.inputs.iter().zip(inputs).enumerate() {
        let numel = lit.element_count();
        if numel != sig.numel() {
            bail!(
                "{model}/{step} input {i}: expected {:?} ({} elements), \
                 literal has {}",
                sig.shape,
                sig.numel(),
                numel
            );
        }
        let want = match sig.dtype {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
        };
        if let Ok(ty) = lit.ty() {
            if ty != want {
                bail!(
                    "{model}/{step} input {i}: dtype mismatch \
                     (manifest {want:?}, literal {ty:?})"
                );
            }
        }
    }
    Ok(())
}

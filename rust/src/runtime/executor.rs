//! Session: one PJRT CPU client + a cache of compiled executables.
//!
//! One `Session` per worker thread (PJRT wrapper types are not `Send`).
//! Artifacts are compiled lazily on first use and cached for the life of
//! the session; `execute` validates input arity/shape against the
//! manifest before dispatch so shape bugs surface as errors, not XLA
//! aborts.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifact::{ArtifactSig, DType, Manifest};

/// A per-thread runtime session.
pub struct Session {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<(String, String), PjRtLoadedExecutable>>,
}

impl Session {
    /// Open the artifacts directory (compiles nothing yet).
    pub fn open(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Session {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Open with an already-parsed manifest (tests).
    pub fn with_manifest(manifest: Manifest) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Session {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Ensure `(model, step)` is compiled; returns nothing (warms cache).
    pub fn warm(&self, model: &str, step: &str) -> Result<()> {
        self.compiled(model, step).map(|_| ())
    }

    fn compiled(&self, model: &str, step: &str) -> Result<()> {
        let key = (model.to_string(), step.to_string());
        if self.cache.borrow().contains_key(&key) {
            return Ok(());
        }
        let mm = self.manifest.model(model)?;
        let art = mm.artifact(step)?;
        let path = self.manifest.dir.join(&art.file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().unwrap_or_default(),
        )
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {model}/{step}"))?;
        self.cache.borrow_mut().insert(key, exe);
        Ok(())
    }

    /// Execute `(model, step)` with the given inputs; returns the
    /// untupled outputs as host literals.
    pub fn execute(
        &self,
        model: &str,
        step: &str,
        inputs: &[Literal],
    ) -> Result<Vec<Literal>> {
        let mm = self.manifest.model(model)?;
        let art = mm.artifact(step)?;
        validate_inputs(model, step, art, inputs)?;
        self.compiled(model, step)?;
        let cache = self.cache.borrow();
        let exe = cache
            .get(&(model.to_string(), step.to_string()))
            .expect("compiled() populated the cache");
        let result = exe.execute::<Literal>(inputs)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {model}/{step}"))?;
        // AOT lowers with return_tuple=True: outputs arrive as one tuple.
        let outs = result.to_tuple()?;
        if outs.len() != art.outputs.len() {
            bail!(
                "{model}/{step}: manifest promises {} outputs, got {}",
                art.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

fn validate_inputs(
    model: &str,
    step: &str,
    art: &ArtifactSig,
    inputs: &[Literal],
) -> Result<()> {
    if inputs.len() != art.inputs.len() {
        bail!(
            "{model}/{step}: expected {} inputs, got {}",
            art.inputs.len(),
            inputs.len()
        );
    }
    for (i, (sig, lit)) in art.inputs.iter().zip(inputs).enumerate() {
        let numel = lit.element_count();
        if numel != sig.numel() {
            bail!(
                "{model}/{step} input {i}: expected {:?} ({} elements), \
                 literal has {}",
                sig.shape,
                sig.numel(),
                numel
            );
        }
        let want = match sig.dtype {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
        };
        if let Ok(ty) = lit.ty() {
            if ty != want {
                bail!(
                    "{model}/{step} input {i}: dtype mismatch \
                     (manifest {want:?}, literal {ty:?})"
                );
            }
        }
    }
    Ok(())
}

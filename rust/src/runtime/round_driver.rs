//! Shared L-step inner-round exercisers for benches and integration
//! tests.
//!
//! Three call sites (the dispatch-path bench in
//! `benches/runtime_hot_path.rs` and the two buffer-vs-literal tests in
//! `tests/integration_runtime.rs`) used to carry their own ~70-line
//! copy of the same loop: L dispatches of the `inner_step` artifact,
//! once through the literal-marshalling path and once through the
//! device-resident buffer path. This module is the single copy. It is
//! *not* the training path — `coordinator::replica` owns that — just
//! the standalone harness that proves the two dispatch paths agree
//! bit-for-bit and differ in transfer bytes.
//!
//! Hyperparameters are fixed (`lr 0.1, gain 0.01, alpha 0.75, mu 0.9,
//! wd 0`), the anchor is the start state, and step `i` uses seed `i` —
//! exactly what every call site used, so the collapse changes no
//! numbers.

use anyhow::{Context, Result};
use xla::Literal;

use super::executor::Session;
use super::tensor::{lit_f32, lit_scalar_f32, lit_scalar_i32, scalar_f32,
                    to_f32};

/// One inner round's inputs: the model, the step count, the start state
/// (y0 = z0 = anchor; momentum starts at zero) and a fixed minibatch
/// reused for every step.
pub struct InnerRound<'a> {
    pub model: &'a str,
    pub l_steps: usize,
    pub state0: &'a [f32],
    pub xb: &'a Literal,
    pub yb: &'a Literal,
}

/// End-of-round state plus the per-step losses.
pub struct RoundOut {
    pub y: Vec<f32>,
    pub z: Vec<f32>,
    pub mom: Vec<f32>,
    pub losses: Vec<f32>,
}

const LR: f32 = 0.1;
const GAIN: f32 = 0.01;
const ALPHA: f32 = 0.75;
const MU: f32 = 0.9;
const WD: f32 = 0.0;

/// The literal path: re-marshals y/z/mom/anchor up and y/z/mom down on
/// every step (O(P*L) parameter traffic per round).
pub fn literal_round(session: &Session, r: &InnerRound) -> Result<RoundOut> {
    let p = r.state0.len();
    let mut y = r.state0.to_vec();
    let mut z = r.state0.to_vec();
    let mut mom = vec![0.0f32; p];
    let mut losses = Vec::with_capacity(r.l_steps);
    for step in 0..r.l_steps {
        let outs = session.execute(
            r.model,
            "inner_step",
            &[
                lit_f32(&y, &[p])?,
                lit_f32(&z, &[p])?,
                lit_f32(&mom, &[p])?,
                lit_f32(r.state0, &[p])?,
                r.xb.clone(),
                r.yb.clone(),
                lit_scalar_f32(LR),
                lit_scalar_f32(GAIN),
                lit_scalar_f32(ALPHA),
                lit_scalar_f32(MU),
                lit_scalar_f32(WD),
                lit_scalar_i32(step as i32),
            ],
        )?;
        y = to_f32(&outs[0])?;
        z = to_f32(&outs[1])?;
        mom = to_f32(&outs[2])?;
        losses.push(scalar_f32(&outs[3])?);
    }
    Ok(RoundOut { y, z, mom, losses })
}

/// The buffer path: (y, z, mom), the anchor and the scalar
/// hyperparameters go up once, each step uploads only its seed and
/// downloads only the loss scalar, and the state comes back once after
/// the last step (O(P) parameter traffic per round).
pub fn buffer_round(session: &Session, r: &InnerRound) -> Result<RoundOut> {
    let p = r.state0.len();
    let mut y_buf = session.upload(&lit_f32(r.state0, &[p])?)?;
    let mut z_buf = session.upload(&lit_f32(r.state0, &[p])?)?;
    let mut mom_buf =
        session.upload(&lit_f32(&vec![0.0f32; p], &[p])?)?;
    let anchor = session.upload(&lit_f32(r.state0, &[p])?)?;
    let lr = session.upload(&lit_scalar_f32(LR))?;
    let gain = session.upload(&lit_scalar_f32(GAIN))?;
    let alpha = session.upload(&lit_scalar_f32(ALPHA))?;
    let mu = session.upload(&lit_scalar_f32(MU))?;
    let wd = session.upload(&lit_scalar_f32(WD))?;
    let mut losses = Vec::with_capacity(r.l_steps);
    for step in 0..r.l_steps {
        let xb_buf = session.upload(r.xb)?;
        let yb_buf = session.upload(r.yb)?;
        let seed = session.upload(&lit_scalar_i32(step as i32))?;
        let outs = session.execute_buffers(
            r.model,
            "inner_step",
            &[
                &y_buf, &z_buf, &mom_buf, &anchor, &xb_buf, &yb_buf, &lr,
                &gain, &alpha, &mu, &wd, &seed,
            ],
        )?;
        let mut it = outs.into_iter();
        let mut take = |name: &str| {
            it.next().with_context(|| {
                format!("inner_step: missing {name} output")
            })
        };
        y_buf = take("y")?;
        z_buf = take("z")?;
        mom_buf = take("mom")?;
        let loss = take("loss")?;
        losses.push(scalar_f32(&session.download(&loss)?)?);
    }
    Ok(RoundOut {
        y: to_f32(&session.download(&y_buf)?)?,
        z: to_f32(&session.download(&z_buf)?)?,
        mom: to_f32(&session.download(&mom_buf)?)?,
        losses,
    })
}

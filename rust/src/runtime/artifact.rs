//! Artifact manifest: the typed contract between the python AOT pipeline
//! and the rust runtime, parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element dtype of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(tag: &str) -> Result<Self> {
        match tag {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype tag {other:?}"),
        }
    }
}

/// Shape + dtype of one artifact input or output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        let dtype = DType::parse(j.str_of("dtype")?)?;
        let shape = j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("shape not an array"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSig { dtype, shape })
    }
}

/// One lowered HLO artifact (a step function of one model).
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub sha256: String,
}

/// One named parameter tensor inside the flat vector — the alignment
/// experiment (Fig 1) uses these to find conv filter banks.
#[derive(Clone, Debug)]
pub struct LayerInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Everything the runtime knows about one model.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub param_count: usize,
    pub batch: usize,
    pub scan_l: usize,
    pub dataset: String,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub input_dtype: DType,
    pub label_shape: Vec<usize>,
    pub layers: Vec<LayerInfo>,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

impl ModelManifest {
    pub fn artifact(&self, step: &str) -> Result<&ArtifactSig> {
        self.artifacts.get(step).ok_or_else(|| {
            anyhow!(
                "model {:?} has no artifact {:?} (have: {:?})",
                self.name,
                step,
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Per-example input element count (images: H*W*C; LM: T).
    pub fn input_numel(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Labels per example (1 for classification, T for the LM).
    pub fn labels_per_example(&self) -> usize {
        self.label_shape.iter().product::<usize>().max(1)
    }
}

/// Minimal image-model manifest for unit tests that need shape/batch
/// accounting without artifacts on disk (shared by the coordinator
/// strategy tests).
#[cfg(test)]
pub fn test_manifest(batch: usize) -> ModelManifest {
    ModelManifest {
        name: "mlp_synth".into(),
        param_count: 10,
        batch,
        scan_l: 1,
        dataset: "synth_mnist".into(),
        num_classes: 10,
        input_shape: vec![28, 28, 1],
        input_dtype: DType::F32,
        label_shape: vec![],
        layers: vec![],
        artifacts: BTreeMap::new(),
    }
}

/// The whole parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut models = BTreeMap::new();
        for (name, mj) in j
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models not an object"))?
        {
            models.insert(name.clone(), Self::parse_model(name, mj)?);
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "unknown model {name:?}; manifest has {:?}",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    fn parse_model(name: &str, j: &Json) -> Result<ModelManifest> {
        let parse_dims = |key: &str| -> Result<Vec<usize>> {
            j.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow!("{key} not an array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect()
        };

        let mut artifacts = BTreeMap::new();
        for (step, aj) in j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
        {
            let parse_sigs = |key: &str| -> Result<Vec<TensorSig>> {
                aj.req(key)?
                    .as_arr()
                    .ok_or_else(|| anyhow!("{key} not an array"))?
                    .iter()
                    .map(TensorSig::parse)
                    .collect()
            };
            artifacts.insert(
                step.clone(),
                ArtifactSig {
                    file: aj.str_of("file")?.to_string(),
                    inputs: parse_sigs("inputs")?,
                    outputs: parse_sigs("outputs")?,
                    sha256: aj.str_of("sha256")?.to_string(),
                },
            );
        }

        let mut layers = Vec::new();
        for lj in j
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow!("layers not an array"))?
        {
            layers.push(LayerInfo {
                name: lj.str_of("name")?.to_string(),
                shape: lj
                    .req("shape")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("bad layer shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                offset: lj.usize_of("offset")?,
                size: lj.usize_of("size")?,
            });
        }

        Ok(ModelManifest {
            name: name.to_string(),
            param_count: j.usize_of("param_count")?,
            batch: j.usize_of("batch")?,
            scan_l: j.usize_of("scan_l")?,
            dataset: j.str_of("dataset")?.to_string(),
            num_classes: j.usize_of("num_classes")?,
            input_shape: parse_dims("input_shape")?,
            input_dtype: DType::parse(j.str_of("input_dtype")?)?,
            label_shape: parse_dims("label_shape")?,
            layers,
            artifacts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{"version": 1, "models": {"m": {
            "param_count": 10, "batch": 4, "scan_l": 5,
            "dataset": "synth_gauss", "num_classes": 3,
            "input_shape": [8], "input_dtype": "f32", "label_shape": [],
            "layers": [{"name": "w", "shape": [2, 4], "offset": 0,
                        "size": 8, "init": "he"}],
            "artifacts": {"init": {"file": "m/init.hlo.txt",
                "inputs": [{"dtype": "i32", "shape": []}],
                "outputs": [{"dtype": "f32", "shape": [10]}],
                "sha256": "abc"}}}}}"#
    }

    #[test]
    fn parses_model() {
        let dir = std::env::temp_dir().join("parle_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let mm = m.model("m").unwrap();
        assert_eq!(mm.param_count, 10);
        assert_eq!(mm.batch, 4);
        let a = mm.artifact("init").unwrap();
        assert_eq!(a.inputs[0].dtype, DType::I32);
        assert_eq!(a.outputs[0].numel(), 10);
        assert!(mm.artifact("nope").is_err());
        assert!(m.model("zzz").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}

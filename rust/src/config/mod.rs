//! Typed run configuration + a small `key=value` override parser.
//!
//! Experiments are driven by presets (one per paper table/figure row,
//! see [`crate::experiments`]); the CLI lets any field be overridden with
//! `--set key=value` pairs so ablations don't need code changes.

pub mod run;

pub use run::{Algo, CommCfg, CommMode, RunConfig, ScopingCfg,
              TransportCfg, WireCodec};

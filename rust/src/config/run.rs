//! RunConfig: everything one training run needs.

use anyhow::{bail, Result};

use crate::data::DataConfig;
use crate::opt::LrSchedule;

/// Which algorithm drives the run (§2/§3 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Parle (8a)-(8d): Entropy-SGD inner loop + elastic coupling.
    Parle,
    /// Entropy-SGD (6a)-(6c): sequential, n forced to 1.
    EntropySgd,
    /// Elastic-SGD (7a)-(7b): couple every step through the reference.
    ElasticSgd,
    /// Plain SGD with Nesterov momentum (sequential baseline).
    Sgd,
    /// Synchronous data-parallel SGD (gradient averaging across workers).
    SgdDataParallel,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s {
            "parle" => Algo::Parle,
            "entropy-sgd" | "entropy" => Algo::EntropySgd,
            "elastic-sgd" | "elastic" => Algo::ElasticSgd,
            "sgd" => Algo::Sgd,
            "sgd-dp" | "sgd-data-parallel" => Algo::SgdDataParallel,
            other => bail!("unknown algo {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Parle => "parle",
            Algo::EntropySgd => "entropy-sgd",
            Algo::ElasticSgd => "elastic-sgd",
            Algo::Sgd => "sgd",
            Algo::SgdDataParallel => "sgd-dp",
        }
    }
}

/// How the master exchanges with replicas each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommMode {
    /// The paper's synchronous round barrier: broadcast, collect every
    /// replica's report, reduce. Deterministic given a seed.
    Sync,
    /// Asynchronous elastic updates (EASGD-style): each replica runs
    /// its L-step legs continuously against its last-seen reference
    /// while the master applies partial updates per arriving report,
    /// bounded by `max_staleness`. Wall-clock-robust to stragglers;
    /// master update order (hence the trajectory) is not deterministic.
    Async,
}

impl CommMode {
    pub fn parse(s: &str) -> Result<CommMode> {
        Ok(match s {
            "sync" => CommMode::Sync,
            "async" => CommMode::Async,
            other => bail!("unknown comm mode {other:?} (sync|async)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CommMode::Sync => "sync",
            CommMode::Async => "async",
        }
    }
}

/// Which transport the communication fabric runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportCfg {
    /// Zero-copy in-process MPSC channels (default): replicas are
    /// worker threads of the master process.
    InProcess,
    /// Length-prefixed TCP: replicas are remote worker processes. The
    /// master listens on `RunConfig::listen`; workers run
    /// `--role worker --connect host:port` with the same config.
    /// Sync-mode outputs are bit-identical to the in-process transport;
    /// the simulated-interconnect model is skipped (wire time is real).
    Tcp,
}

impl TransportCfg {
    pub fn parse(s: &str) -> Result<TransportCfg> {
        Ok(match s {
            "in-process" | "channels" => TransportCfg::InProcess,
            "tcp" => TransportCfg::Tcp,
            other => bail!("unknown transport {other:?} (in-process|tcp)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportCfg::InProcess => "in-process",
            TransportCfg::Tcp => "tcp",
        }
    }
}

/// Payload codec applied to the TCP wire legs (`--wire-codec`). Purely
/// a transport-representation knob: `raw` ships LE f32 frames exactly
/// as before (the determinism-suite default), the lossy codecs
/// quantize/sparsify the report leg under per-replica error feedback
/// and compress the broadcast leg. Negotiated in the hello handshake —
/// a codec-mismatched worker is refused at connect. In-process
/// channels ignore it (no wire to compress).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WireCodec {
    /// Bit-identical LE f32 frames on both legs (default).
    Raw,
    /// bf16 quantization on both legs; the report leg carries an
    /// error-feedback residual so the elastic mean doesn't drift.
    Bf16,
    /// IEEE binary16 on both legs; report leg error-fed like `Bf16`.
    F16,
    /// Top-k sparsification of the report leg (k = this fraction of P,
    /// residual-fed); the broadcast leg ships bf16.
    TopK(f32),
    /// XOR-delta broadcast leg against the previous dispatch slab; the
    /// report leg stays raw, so the trajectory is bit-identical to
    /// `Raw` — pure byte savings.
    Delta,
    /// Delta-encoded bf16 broadcast leg plus the `Bf16` report leg:
    /// trajectory bit-identical to `Bf16` with fewer broadcast bytes.
    DeltaBf16,
}

impl WireCodec {
    pub fn parse(s: &str) -> Result<WireCodec> {
        Ok(match s {
            "raw" => WireCodec::Raw,
            "bf16" => WireCodec::Bf16,
            "f16" => WireCodec::F16,
            "delta" => WireCodec::Delta,
            "delta+bf16" | "delta-bf16" => WireCodec::DeltaBf16,
            other => {
                let Some(frac) = other.strip_prefix("topk") else {
                    bail!(
                        "unknown wire codec {other:?} \
                         (raw|bf16|f16|topk<K>|delta|delta+bf16)"
                    );
                };
                let k: f32 = frac.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "bad top-k fraction {frac:?} in {other:?} \
                         (e.g. topk0.01)"
                    )
                })?;
                if !(k > 0.0 && k <= 1.0) {
                    bail!("top-k fraction must be in (0, 1], got {k}");
                }
                WireCodec::TopK(k)
            }
        })
    }

    pub fn name(&self) -> String {
        match self {
            WireCodec::Raw => "raw".to_string(),
            WireCodec::Bf16 => "bf16".to_string(),
            WireCodec::F16 => "f16".to_string(),
            WireCodec::TopK(k) => format!("topk{k}"),
            WireCodec::Delta => "delta".to_string(),
            WireCodec::DeltaBf16 => "delta+bf16".to_string(),
        }
    }
}

/// Scoping mode for gamma/rho (eq. 9).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScopingCfg {
    /// Paper schedule: gamma0=100, rho0=1, decay (1-1/2B)^(k/L).
    Paper,
    /// Constant values (the §4.4 "no scoping" ablation).
    Constant { gamma: f32, rho: f32 },
}

/// Optional simulated-interconnect model applied to every reduce.
#[derive(Clone, Copy, Debug)]
pub struct CommCfg {
    /// Per-message latency in seconds (0 disables simulation).
    pub latency_s: f64,
    /// Link bandwidth in bytes/s (f64::INFINITY disables).
    pub bandwidth_bps: f64,
}

impl CommCfg {
    pub fn off() -> Self {
        CommCfg {
            latency_s: 0.0,
            bandwidth_bps: f64::INFINITY,
        }
    }

    /// PCI-E 3.0 x16-ish profile (the paper's testbed interconnect).
    pub fn pcie() -> Self {
        CommCfg {
            latency_s: 10e-6,
            bandwidth_bps: 12e9,
        }
    }

    /// Commodity 10 GbE cluster profile (distributed deployment).
    pub fn ethernet_10g() -> Self {
        CommCfg {
            latency_s: 50e-6,
            bandwidth_bps: 1.1e9,
        }
    }

    pub fn is_off(&self) -> bool {
        self.latency_s == 0.0 && self.bandwidth_bps.is_infinite()
    }

    /// Simulated transfer time for a payload.
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

/// Boolean `--set` flag accepting `1/0` as well as `true/false` (the
/// documented spelling is `--set async_lr_rescale=1`).
fn parse_flag(value: &str) -> Result<bool> {
    match value {
        "1" | "true" | "on" => Ok(true),
        "0" | "false" | "off" => Ok(false),
        other => bail!("expected a boolean flag (1/0/true/false), \
                        got {other:?}"),
    }
}

/// Full specification of one training run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub algo: Algo,
    /// Number of replicas n (forced to 1 for sequential algorithms).
    pub replicas: usize,
    /// Training length in epochs over the (per-replica) training set.
    pub epochs: f64,
    /// Communication period L (minibatches between reduces). The paper
    /// fixes L=25 for Parle/Entropy-SGD and L=1 for Elastic-SGD.
    pub l_steps: usize,
    /// Exponential-average factor alpha (8b); paper: 0.75.
    pub alpha: f32,
    /// Nesterov momentum; paper: 0.9.
    pub momentum: f32,
    pub lr: LrSchedule,
    pub weight_decay: f32,
    pub scoping: ScopingCfg,
    pub data: DataConfig,
    /// §5: split the training set into disjoint shards, one per replica.
    pub split_data: bool,
    /// Evaluate on the validation set every this many communication
    /// rounds (0 = only at the end).
    pub eval_every_rounds: usize,
    /// Use the fused L-step scan artifact instead of per-step dispatch.
    pub use_scan: bool,
    pub comm: CommCfg,
    /// Synchronous round barrier (default) or asynchronous elastic
    /// updates on the event fabric.
    pub comm_mode: CommMode,
    /// Async only: how many rounds a replica may run ahead of the
    /// slowest unfinished replica before the master holds it back
    /// (0 = lockstep). Ignored in sync mode.
    pub max_staleness: usize,
    /// Async `sgd-dp` only: rescale the per-gradient Nesterov LR by
    /// 1/replicas (the Downpour effective-batch correction — n
    /// single-batch async steps then match one barrier step on the
    /// n-batch mean gradient to first order). `--set async_lr_rescale=1`.
    pub async_lr_rescale: bool,
    /// Sync mode: split each round's parameter vector into buckets of
    /// this many **bytes** so the master reduces early buckets while
    /// later ones are still in flight (streaming reduce). `0` restores
    /// the legacy whole-vector round. Purely a comm-layer knob: the
    /// reduced means are bit-identical for every value, so it is
    /// excluded from the replay fingerprint. Ignored in async mode.
    pub reduce_bucket_bytes: usize,
    /// Fabric transport: in-process worker threads (default) or TCP to
    /// remote worker processes.
    pub transport: TransportCfg,
    /// TCP payload codec (`--wire-codec`): `raw` (default) ships LE f32
    /// both ways; lossy codecs compress the legs under error feedback.
    /// Negotiated at connect; ignored by in-process channels.
    pub wire_codec: WireCodec,
    /// TCP master only: `host:port` to listen on for worker
    /// connections (`--listen`).
    pub listen: Option<String>,
    /// TCP worker: ping the master with a heartbeat frame after this
    /// many seconds of command-leg idleness so the master's liveness
    /// clock stays fresh between round legs (`--heartbeat-every`;
    /// 0 disables pings). A pure transport-liveness knob — excluded
    /// from the replay fingerprint like the rest of the wire layer.
    pub heartbeat_secs: f64,
    /// TCP master: evict a replica silent for this many seconds — its
    /// shard parked, barriers shrink to the live members, and the
    /// listener keeps admitting fingerprint-matched late joiners
    /// (`--evict-after`). 0 (the default) keeps the classic fail-stop
    /// fabric.
    pub evict_after_secs: f64,
    /// TCP worker: fail with a typed "master silent" error once no
    /// master frame has arrived for this many seconds
    /// (`--master-silence`; 0 = wait forever, the legacy behavior).
    pub master_silence_secs: f64,
    pub seed: u64,
    pub artifacts_dir: String,
    /// Write a full-state checkpoint every this many communication
    /// rounds (0 = never). See `checkpoint_path`.
    pub checkpoint_every_rounds: usize,
    /// Checkpoint destination; a `{round}` placeholder is substituted
    /// with the 1-based round index (keeps history instead of
    /// overwriting). Defaults to `checkpoints/<label>.ck`.
    pub checkpoint_path: Option<String>,
    /// Resume a run from a round-granular checkpoint written by
    /// `checkpoint_every_rounds`; the resumed run reproduces the
    /// uninterrupted run's final params and curve.
    pub resume_from: Option<String>,
    /// Run evaluation on a dedicated thread/session so the validation
    /// sweep overlaps the next round's compute (default). `false`
    /// evaluates inside the round barrier, as before the engine
    /// refactor; both modes produce identical records up to wall-clock.
    pub overlap_eval: bool,
}

impl RunConfig {
    /// Sensible defaults for a model (paper hyper-parameters §3.1).
    pub fn new(model: &str, algo: Algo) -> Self {
        let replicas = match algo {
            Algo::Sgd | Algo::EntropySgd => 1,
            _ => 3,
        };
        let l_steps = match algo {
            Algo::ElasticSgd | Algo::Sgd | Algo::SgdDataParallel => 1,
            _ => 25,
        };
        RunConfig {
            model: model.to_string(),
            algo,
            replicas,
            epochs: 3.0,
            l_steps,
            alpha: 0.75,
            momentum: 0.9,
            lr: LrSchedule::new(0.1, vec![2, 4, 6], 5.0),
            weight_decay: 5e-4,
            scoping: ScopingCfg::Paper,
            data: DataConfig::default(),
            split_data: false,
            eval_every_rounds: 10,
            use_scan: false,
            comm: CommCfg::off(),
            comm_mode: CommMode::Sync,
            max_staleness: 4,
            async_lr_rescale: false,
            reduce_bucket_bytes: 16 << 20,
            transport: TransportCfg::InProcess,
            wire_codec: WireCodec::Raw,
            listen: None,
            heartbeat_secs: 2.0,
            evict_after_secs: 0.0,
            master_silence_secs: 0.0,
            seed: 42,
            artifacts_dir: "artifacts".to_string(),
            checkpoint_every_rounds: 0,
            checkpoint_path: None,
            resume_from: None,
            overlap_eval: true,
        }
    }

    /// Apply a `key=value` override; returns an error for unknown keys.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "model" => self.model = value.to_string(),
            "algo" => self.algo = Algo::parse(value)?,
            "replicas" | "n" => self.replicas = value.parse()?,
            "epochs" => self.epochs = value.parse()?,
            "l" | "l_steps" => self.l_steps = value.parse()?,
            "alpha" => self.alpha = value.parse()?,
            "momentum" => self.momentum = value.parse()?,
            "lr" => self.lr.base = value.parse()?,
            "wd" | "weight_decay" => self.weight_decay = value.parse()?,
            "train" => self.data.train = value.parse()?,
            "val" => self.data.val = value.parse()?,
            "difficulty" => self.data.difficulty = value.parse()?,
            "split_data" => self.split_data = value.parse()?,
            "eval_every" => self.eval_every_rounds = value.parse()?,
            "use_scan" => self.use_scan = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "artifacts" => self.artifacts_dir = value.to_string(),
            "checkpoint_every" | "checkpoint_every_rounds" => {
                self.checkpoint_every_rounds = value.parse()?
            }
            "checkpoint_path" => {
                self.checkpoint_path = Some(value.to_string())
            }
            "overlap_eval" => self.overlap_eval = value.parse()?,
            "comm_mode" => self.comm_mode = CommMode::parse(value)?,
            "max_staleness" => self.max_staleness = value.parse()?,
            "async_lr_rescale" => {
                self.async_lr_rescale = parse_flag(value)?
            }
            "reduce_bucket_bytes" | "bucket_bytes" => {
                self.reduce_bucket_bytes = value.parse()?
            }
            "transport" => self.transport = TransportCfg::parse(value)?,
            "wire_codec" | "codec" => {
                self.wire_codec = WireCodec::parse(value)?
            }
            "listen" => self.listen = Some(value.to_string()),
            "heartbeat_every" | "heartbeat_secs" => {
                self.heartbeat_secs = value.parse()?
            }
            "evict_after" | "evict_after_secs" => {
                self.evict_after_secs = value.parse()?
            }
            "master_silence" | "master_silence_secs" => {
                self.master_silence_secs = value.parse()?
            }
            "scoping" => {
                self.scoping = match value {
                    "paper" => ScopingCfg::Paper,
                    "off" => ScopingCfg::Constant {
                        gamma: 100.0,
                        rho: 1.0,
                    },
                    other => bail!("unknown scoping {other:?}"),
                }
            }
            "comm" => {
                self.comm = match value {
                    "off" => CommCfg::off(),
                    "pcie" => CommCfg::pcie(),
                    "10g" => CommCfg::ethernet_10g(),
                    other => bail!("unknown comm profile {other:?}"),
                }
            }
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// FNV-1a hash over every field that determines the training
    /// trajectory's replay: data synthesis/sharding, hyperparameters,
    /// the LR/scoping schedules, and the dispatch mode. Checkpoints
    /// stamp it so `--resume` can refuse a run whose RNG streams or
    /// schedules would silently diverge from the checkpointed one.
    /// Deliberately excludes fields that do not change the parameter
    /// trajectory: epochs (resuming with more epochs extends a run),
    /// eval cadence, comm simulation, checkpoint/output settings.
    /// `comm_mode`/`max_staleness`/`async_lr_rescale` are also
    /// excluded: async runs are not replay-deterministic anyway, and
    /// the one hazardous crossing (resuming a sync run from an async
    /// checkpoint with uneven per-replica round stamps) is rejected
    /// structurally by the engine. `transport`/`listen` are excluded
    /// because sync-mode training is bit-identical across transports —
    /// a checkpoint written over TCP resumes in-process and vice versa.
    /// `reduce_bucket_bytes` is likewise excluded: the streaming
    /// bucketed reduce is bit-identical to the monolithic one for every
    /// bucket size (pinned by the fabric's cross-bucket-size equality
    /// tests), so a checkpoint resumes under any bucketing.
    /// `wire_codec` is excluded for the same transport-layer reason:
    /// it is negotiated per connection, the error-feedback residuals a
    /// lossy codec carries are checkpointed as worker state (so resume
    /// stays trajectory-stable under the *same* codec), and `raw` /
    /// `delta` don't perturb the trajectory at all. Resuming under a
    /// different lossy codec changes future rounding, exactly like
    /// resuming on different BLAS hardware — permitted, not
    /// fingerprinted. The elastic-membership knobs
    /// (`heartbeat_secs`/`evict_after_secs`/`master_silence_secs`) are
    /// liveness policy, not trajectory: they stay out too, so a
    /// fail-stop checkpoint resumes under an elastic fabric and vice
    /// versa — and a late joiner's hello fingerprint matches the
    /// master's regardless of either side's liveness settings.
    pub fn replay_fingerprint(&self) -> u64 {
        let canon = format!(
            "model={};alpha={};momentum={};wd={};lr={}@{:?}/{};\
             scoping={:?};train={};val={};difficulty={};dseed={};\
             split={};scan={}",
            self.model,
            self.alpha,
            self.momentum,
            self.weight_decay,
            self.lr.base,
            self.lr.drop_epochs,
            self.lr.drop_factor,
            self.scoping,
            self.data.train,
            self.data.val,
            self.data.difficulty,
            self.data.seed,
            self.split_data,
            self.use_scan,
        );
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in canon.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// Consistency checks before a run starts.
    pub fn validate(&self) -> Result<()> {
        if self.replicas == 0 {
            bail!("replicas must be >= 1");
        }
        if matches!(self.algo, Algo::Sgd | Algo::EntropySgd)
            && self.replicas != 1
        {
            bail!(
                "{} is sequential; set replicas=1 (got {})",
                self.algo.name(),
                self.replicas
            );
        }
        if self.l_steps == 0 {
            bail!("l_steps must be >= 1");
        }
        if self.split_data && self.replicas < 2 {
            bail!("split_data needs >= 2 replicas");
        }
        if !(0.0..=1.0).contains(&self.alpha) {
            bail!("alpha must be in [0, 1]");
        }
        for (name, v) in [
            ("heartbeat_every", self.heartbeat_secs),
            ("evict_after", self.evict_after_secs),
            ("master_silence", self.master_silence_secs),
        ] {
            if !(v >= 0.0 && v.is_finite()) {
                bail!("{name} must be a finite number of seconds >= 0, \
                       got {v}");
            }
        }
        if self.evict_after_secs > 0.0
            && self.heartbeat_secs > 0.0
            && self.heartbeat_secs >= self.evict_after_secs
        {
            bail!(
                "heartbeat_every ({}s) must be shorter than evict_after \
                 ({}s), or every worker gets evicted between pings",
                self.heartbeat_secs,
                self.evict_after_secs
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_roundtrip() {
        for a in [
            Algo::Parle,
            Algo::EntropySgd,
            Algo::ElasticSgd,
            Algo::Sgd,
            Algo::SgdDataParallel,
        ] {
            assert_eq!(Algo::parse(a.name()).unwrap(), a);
        }
        assert!(Algo::parse("momentum").is_err());
    }

    #[test]
    fn overrides() {
        let mut c = RunConfig::new("mlp_synth", Algo::Parle);
        c.set("replicas", "6").unwrap();
        c.set("epochs", "1.5").unwrap();
        c.set("lr", "0.05").unwrap();
        c.set("scoping", "off").unwrap();
        assert_eq!(c.replicas, 6);
        assert_eq!(c.epochs, 1.5);
        assert_eq!(c.lr.base, 0.05);
        assert!(matches!(c.scoping, ScopingCfg::Constant { .. }));
        assert!(c.set("bogus", "1").is_err());
    }

    /// The fingerprint must move with replay-relevant fields and stay
    /// put for the excluded ones (epochs, eval cadence, comm, output).
    #[test]
    fn replay_fingerprint_tracks_the_right_fields() {
        let base = RunConfig::new("mlp_synth", Algo::Parle);
        let fp = base.replay_fingerprint();
        assert_eq!(fp, base.clone().replay_fingerprint());
        let mut c = base.clone();
        c.data.train = 999;
        assert_ne!(fp, c.replay_fingerprint());
        let mut c = base.clone();
        c.use_scan = true;
        assert_ne!(fp, c.replay_fingerprint());
        let mut c = base.clone();
        c.lr.base = 0.01;
        assert_ne!(fp, c.replay_fingerprint());
        let mut c = base.clone();
        c.scoping = ScopingCfg::Constant {
            gamma: 100.0,
            rho: 1.0,
        };
        assert_ne!(fp, c.replay_fingerprint());
        // excluded: a longer run or denser eval may resume freely
        let mut c = base.clone();
        c.epochs = 30.0;
        c.eval_every_rounds = 1;
        c.checkpoint_every_rounds = 7;
        assert_eq!(fp, c.replay_fingerprint());
    }

    #[test]
    fn comm_mode_parse_and_overrides() {
        assert_eq!(CommMode::parse("sync").unwrap(), CommMode::Sync);
        assert_eq!(CommMode::parse("async").unwrap(), CommMode::Async);
        assert!(CommMode::parse("gossip").is_err());
        assert_eq!(CommMode::Async.name(), "async");
        let mut c = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(c.comm_mode, CommMode::Sync);
        c.set("comm_mode", "async").unwrap();
        c.set("max_staleness", "2").unwrap();
        assert_eq!(c.comm_mode, CommMode::Async);
        assert_eq!(c.max_staleness, 2);
        assert!(c.validate().is_ok());
        // mode/staleness do not perturb the replay fingerprint (see
        // replay_fingerprint doc)
        let base = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(base.replay_fingerprint(), c.replay_fingerprint());
    }

    #[test]
    fn transport_parse_and_overrides() {
        assert_eq!(
            TransportCfg::parse("tcp").unwrap(),
            TransportCfg::Tcp
        );
        assert_eq!(
            TransportCfg::parse("in-process").unwrap(),
            TransportCfg::InProcess
        );
        assert!(TransportCfg::parse("carrier-pigeon").is_err());
        let mut c = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(c.transport, TransportCfg::InProcess);
        assert!(c.listen.is_none());
        c.set("transport", "tcp").unwrap();
        c.set("listen", "127.0.0.1:4700").unwrap();
        assert_eq!(c.transport, TransportCfg::Tcp);
        assert_eq!(c.listen.as_deref(), Some("127.0.0.1:4700"));
        // transport choice must not move the replay fingerprint: sync
        // runs are bit-identical across transports, so checkpoints
        // resume across them
        let base = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(base.replay_fingerprint(), c.replay_fingerprint());
    }

    #[test]
    fn async_lr_rescale_flag_accepts_numeric_spelling() {
        let mut c = RunConfig::new("mlp_synth", Algo::SgdDataParallel);
        assert!(!c.async_lr_rescale);
        c.set("async_lr_rescale", "1").unwrap();
        assert!(c.async_lr_rescale);
        c.set("async_lr_rescale", "0").unwrap();
        assert!(!c.async_lr_rescale);
        c.set("async_lr_rescale", "true").unwrap();
        assert!(c.async_lr_rescale);
        assert!(c.set("async_lr_rescale", "maybe").is_err());
        // excluded from the replay fingerprint, like comm_mode
        let base = RunConfig::new("mlp_synth", Algo::SgdDataParallel);
        assert_eq!(base.replay_fingerprint(), c.replay_fingerprint());
    }

    #[test]
    fn reduce_bucket_bytes_overrides_and_fingerprint() {
        let mut c = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(c.reduce_bucket_bytes, 16 << 20);
        c.set("reduce_bucket_bytes", "4096").unwrap();
        assert_eq!(c.reduce_bucket_bytes, 4096);
        c.set("bucket_bytes", "0").unwrap();
        assert_eq!(c.reduce_bucket_bytes, 0);
        assert!(c.set("reduce_bucket_bytes", "lots").is_err());
        assert!(c.validate().is_ok());
        // a comm-layer knob: the bucketed reduce is bit-identical to
        // the monolithic one, so the replay fingerprint ignores it
        let base = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(base.replay_fingerprint(), c.replay_fingerprint());
    }

    #[test]
    fn wire_codec_parse_overrides_and_fingerprint() {
        for (s, c) in [
            ("raw", WireCodec::Raw),
            ("bf16", WireCodec::Bf16),
            ("f16", WireCodec::F16),
            ("delta", WireCodec::Delta),
            ("delta+bf16", WireCodec::DeltaBf16),
            ("topk0.01", WireCodec::TopK(0.01)),
        ] {
            assert_eq!(WireCodec::parse(s).unwrap(), c, "{s}");
        }
        // name() round-trips, including the parametrized spelling
        for c in [
            WireCodec::Raw,
            WireCodec::Bf16,
            WireCodec::F16,
            WireCodec::Delta,
            WireCodec::DeltaBf16,
            WireCodec::TopK(0.125),
        ] {
            assert_eq!(WireCodec::parse(&c.name()).unwrap(), c);
        }
        assert!(WireCodec::parse("gzip").is_err());
        assert!(WireCodec::parse("topk").is_err());
        assert!(WireCodec::parse("topk0").is_err());
        assert!(WireCodec::parse("topk1.5").is_err());
        let mut c = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(c.wire_codec, WireCodec::Raw);
        c.set("wire_codec", "bf16").unwrap();
        assert_eq!(c.wire_codec, WireCodec::Bf16);
        c.set("codec", "topk0.05").unwrap();
        assert_eq!(c.wire_codec, WireCodec::TopK(0.05));
        assert!(c.set("wire_codec", "morse").is_err());
        assert!(c.validate().is_ok());
        // a transport-representation knob: excluded from the replay
        // fingerprint like transport/reduce_bucket_bytes (see the
        // replay_fingerprint doc for the lossy-resume caveat)
        let base = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(base.replay_fingerprint(), c.replay_fingerprint());
    }

    #[test]
    fn membership_knobs_parse_validate_and_stay_unfingerprinted() {
        let mut c = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(c.heartbeat_secs, 2.0);
        assert_eq!(c.evict_after_secs, 0.0);
        assert_eq!(c.master_silence_secs, 0.0);
        c.set("heartbeat_every", "0.5").unwrap();
        c.set("evict_after", "6").unwrap();
        c.set("master_silence", "30").unwrap();
        assert_eq!(c.heartbeat_secs, 0.5);
        assert_eq!(c.evict_after_secs, 6.0);
        assert_eq!(c.master_silence_secs, 30.0);
        assert!(c.set("evict_after", "soon").is_err());
        assert!(c.validate().is_ok());
        // liveness policy, not trajectory: excluded from the replay
        // fingerprint so fail-stop checkpoints resume under an elastic
        // fabric (and late joiners' hellos match the master's print)
        let base = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(base.replay_fingerprint(), c.replay_fingerprint());
        // a heartbeat slower than the eviction deadline is a config
        // error — every worker would look dead between pings
        c.set("heartbeat_every", "10").unwrap();
        assert!(c.validate().is_err());
        c.set("heartbeat_every", "0").unwrap();
        assert!(c.validate().is_ok(), "no pings: reports must suffice");
        c.set("master_silence", "-1").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn checkpoint_and_eval_overrides() {
        let mut c = RunConfig::new("mlp_synth", Algo::Parle);
        assert_eq!(c.checkpoint_every_rounds, 0);
        assert!(c.overlap_eval);
        c.set("checkpoint_every", "5").unwrap();
        c.set("checkpoint_path", "out/ck_{round}.ck").unwrap();
        c.set("overlap_eval", "false").unwrap();
        assert_eq!(c.checkpoint_every_rounds, 5);
        assert_eq!(c.checkpoint_path.as_deref(), Some("out/ck_{round}.ck"));
        assert!(!c.overlap_eval);
    }

    #[test]
    fn validation() {
        let mut c = RunConfig::new("mlp_synth", Algo::Sgd);
        assert!(c.validate().is_ok());
        c.replicas = 3;
        assert!(c.validate().is_err());
        let mut c = RunConfig::new("mlp_synth", Algo::Parle);
        c.split_data = true;
        c.replicas = 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn comm_profiles() {
        assert!(CommCfg::off().is_off());
        let p = CommCfg::pcie();
        // 100 MB over pci-e ~ 8.3 ms + latency
        let t = p.transfer_s(100_000_000);
        assert!(t > 8e-3 && t < 10e-3, "{t}");
    }
}

//! Greedy layer-wise permutation alignment of conv networks (§1.2).
//!
//! Works on flat parameter vectors using the manifest's layer table.
//! A [`ConvStack`] describes the chain of conv layers (HWIO weights);
//! aligning network B to network A walks the chain, matches out-channels
//! with the exact assignment solver, and applies the permutation to the
//! layer's out-channels *and* the next layer's in-channels — preserving
//! the function B computes exactly (up to GroupNorm group boundaries,
//! same caveat as the paper's BatchNorm).

use anyhow::{anyhow, Result};

use crate::align::assignment::hungarian;
use crate::align::overlap::cosine;
use crate::runtime::LayerInfo;

/// One conv layer inside the flat vector.
#[derive(Clone, Debug)]
pub struct ConvLayer {
    pub name: String,
    pub w_off: usize,
    /// HWIO dims
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub b_off: Option<usize>,
    pub gn_scale_off: Option<usize>,
    pub gn_offset_off: Option<usize>,
}

/// A simple feed-forward chain of conv layers (the All-CNN shape).
#[derive(Clone, Debug)]
pub struct ConvStack {
    pub layers: Vec<ConvLayer>,
}

impl ConvStack {
    /// Build from the manifest layer table for All-CNN-style models:
    /// layers named `cN.w` / `cN.b` / `cN.gn.scale` / `cN.gn.offset`.
    pub fn from_layer_table(layers: &[LayerInfo]) -> Result<ConvStack> {
        let find = |name: &str| layers.iter().find(|l| l.name == name);
        let mut out = Vec::new();
        for i in 1.. {
            let w = match find(&format!("c{i}.w")) {
                Some(w) => w,
                None => break,
            };
            if w.shape.len() != 4 {
                return Err(anyhow!("{} is not a conv weight", w.name));
            }
            out.push(ConvLayer {
                name: format!("c{i}"),
                w_off: w.offset,
                kh: w.shape[0],
                kw: w.shape[1],
                cin: w.shape[2],
                cout: w.shape[3],
                b_off: find(&format!("c{i}.b")).map(|l| l.offset),
                gn_scale_off: find(&format!("c{i}.gn.scale"))
                    .map(|l| l.offset),
                gn_offset_off: find(&format!("c{i}.gn.offset"))
                    .map(|l| l.offset),
            });
        }
        if out.len() < 2 {
            return Err(anyhow!("need at least 2 conv layers to align"));
        }
        Ok(ConvStack { layers: out })
    }

    /// Extract out-channel filters of layer `l` as `cout` rows.
    pub fn filters(&self, params: &[f32], l: usize) -> Vec<Vec<f32>> {
        let lay = &self.layers[l];
        let flen = lay.kh * lay.kw * lay.cin;
        let mut rows = vec![Vec::with_capacity(flen); lay.cout];
        // HWIO layout: index = ((h*kw + w)*cin + ci)*cout + co
        for spatial in 0..flen {
            for (co, row) in rows.iter_mut().enumerate() {
                row.push(params[lay.w_off + spatial * lay.cout + co]);
            }
        }
        rows
    }
}

/// Apply an out-channel permutation to layer `l` of `params`
/// (perm[slot] = source channel), including the next layer's in-channels.
fn apply_perm(stack: &ConvStack, params: &mut [f32], l: usize,
              perm: &[usize]) {
    let lay = &stack.layers[l];
    let flen = lay.kh * lay.kw * lay.cin;

    // out-channels of W[l]
    let mut neww = vec![0.0f32; flen * lay.cout];
    for spatial in 0..flen {
        for (slot, &src) in perm.iter().enumerate() {
            neww[spatial * lay.cout + slot] =
                params[lay.w_off + spatial * lay.cout + src];
        }
    }
    params[lay.w_off..lay.w_off + neww.len()].copy_from_slice(&neww);

    // per-channel vectors
    for off in [lay.b_off, lay.gn_scale_off, lay.gn_offset_off]
        .into_iter()
        .flatten()
    {
        let old: Vec<f32> = params[off..off + lay.cout].to_vec();
        for (slot, &src) in perm.iter().enumerate() {
            params[off + slot] = old[src];
        }
    }

    // in-channels of W[l+1]
    if l + 1 < stack.layers.len() {
        let nxt = &stack.layers[l + 1];
        debug_assert_eq!(nxt.cin, lay.cout);
        let sp = nxt.kh * nxt.kw;
        let mut neww = vec![0.0f32; sp * nxt.cin * nxt.cout];
        for s in 0..sp {
            for (slot, &src) in perm.iter().enumerate() {
                for co in 0..nxt.cout {
                    neww[(s * nxt.cin + slot) * nxt.cout + co] = params
                        [nxt.w_off + (s * nxt.cin + src) * nxt.cout + co];
                }
            }
        }
        params[nxt.w_off..nxt.w_off + neww.len()].copy_from_slice(&neww);
    }
}

/// Align `b` to `a` (greedy layer-wise, exact matching per layer).
/// Returns the aligned copy of `b` plus per-layer overlap before/after.
pub fn align_to(
    stack: &ConvStack,
    a: &[f32],
    b: &[f32],
) -> (Vec<f32>, Vec<(String, f64, f64)>) {
    let mut out = b.to_vec();
    let mut report = Vec::new();
    // the last layer's out-channels are the class logits: fixed
    for l in 0..stack.layers.len() - 1 {
        let fa = stack.filters(a, l);
        let fb = stack.filters(&out, l);
        let score: Vec<Vec<f64>> = fa
            .iter()
            .map(|ra| fb.iter().map(|rb| cosine(ra, rb)).collect())
            .collect();
        let before: f64 = (0..fa.len())
            .map(|i| score[i][i])
            .sum::<f64>()
            / fa.len() as f64;
        let perm = hungarian(&score);
        let after: f64 = perm
            .iter()
            .enumerate()
            .map(|(i, &j)| score[i][j])
            .sum::<f64>()
            / fa.len() as f64;
        apply_perm(stack, &mut out, l, &perm);
        report.push((stack.layers[l].name.clone(), before, after));
    }
    (out, report)
}

/// Plain average of several parameter vectors ("one-shot averaging").
pub fn average_params(nets: &[Vec<f32>]) -> Vec<f32> {
    assert!(!nets.is_empty());
    let p = nets[0].len();
    let mut out = vec![0.0f32; p];
    for net in nets {
        for (o, &x) in out.iter_mut().zip(net) {
            *o += x;
        }
    }
    let inv = 1.0 / nets.len() as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Tiny 3-layer stack for tests: c1 3x3x2->4, c2 3x3x4->4, c3 1x1x4->3
    fn test_stack() -> (ConvStack, usize) {
        let mut layers = Vec::new();
        let mut off = 0usize;
        let dims = [(3, 3, 2, 4), (3, 3, 4, 4), (1, 1, 4, 3)];
        for (i, &(kh, kw, cin, cout)) in dims.iter().enumerate() {
            let w = LayerInfo {
                name: format!("c{}.w", i + 1),
                shape: vec![kh, kw, cin, cout],
                offset: off,
                size: kh * kw * cin * cout,
            };
            off += w.size;
            let b = LayerInfo {
                name: format!("c{}.b", i + 1),
                shape: vec![cout],
                offset: off,
                size: cout,
            };
            off += cout;
            layers.push(w);
            layers.push(b);
        }
        (ConvStack::from_layer_table(&layers).unwrap(), off)
    }

    fn random_params(p: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0);
        let mut v = vec![0.0f32; p];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    /// Manually permute out-channels of layer l (reference impl used to
    /// build a ground-truth permuted network).
    fn scramble(stack: &ConvStack, params: &[f32], l: usize,
                perm: &[usize]) -> Vec<f32> {
        let mut out = params.to_vec();
        apply_perm(stack, &mut out, l, perm);
        out
    }

    #[test]
    fn alignment_recovers_scrambled_network() {
        let (stack, p) = test_stack();
        let a = random_params(p, 1);
        // b = a with hidden layers permuted
        let b = scramble(&stack, &a, 0, &[2, 0, 3, 1]);
        let b = scramble(&stack, &b, 1, &[1, 3, 0, 2]);
        // before alignment, networks differ
        assert!(a.iter().zip(&b).any(|(x, y)| (x - y).abs() > 1e-6));
        let (aligned, report) = align_to(&stack, &a, &b);
        for (i, (x, y)) in a.iter().zip(&aligned).enumerate() {
            assert!(
                (x - y).abs() < 1e-5,
                "param {i} differs after alignment: {x} vs {y}"
            );
        }
        for (name, _before, after) in &report {
            assert!(*after > 0.999, "{name} overlap after = {after}");
        }
    }

    #[test]
    fn apply_perm_preserves_multiset() {
        let (stack, p) = test_stack();
        let a = random_params(p, 2);
        let b = scramble(&stack, &a, 0, &[3, 2, 1, 0]);
        let mut sa = a.clone();
        let mut sb = b.clone();
        sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        sb.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(sa, sb); // permutation moves values, loses none
    }

    #[test]
    fn average_params_means() {
        let a = vec![1.0f32, 3.0];
        let b = vec![3.0f32, 5.0];
        assert_eq!(average_params(&[a, b]), vec![2.0, 4.0]);
    }

    #[test]
    fn stack_requires_conv_chain() {
        let layers = vec![LayerInfo {
            name: "fc0.w".into(),
            shape: vec![4, 4],
            offset: 0,
            size: 16,
        }];
        assert!(ConvStack::from_layer_table(&layers).is_err());
    }
}

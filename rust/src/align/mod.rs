//! Permutation alignment of independently trained networks — the §1.2 /
//! Fig. 1 experiment substrate.
//!
//! Deep nets have permutation symmetries: intermediate channels can be
//! reordered (together with the next layer's input channels) without
//! changing the function. The paper aligns 6 independently trained
//! All-CNNs with a greedy layer-wise matching and shows (a) the
//! permutation-invariant overlap is far below 1 (nets live far apart in
//! weight space) and (b) averaging *aligned* weights dramatically beats
//! naive averaging (18.7% vs 89.9% error) — the observation motivating
//! Parle's quadratic coupling.

pub mod assignment;
pub mod overlap;
pub mod permute;

pub use assignment::{greedy_assignment, hungarian};
pub use overlap::{cosine, layer_overlap, OverlapReport};
pub use permute::{align_to, average_params, ConvStack};

//! Permutation-invariant overlap metric (Fig. 1 of the paper).
//!
//! For a layer with `cout` filters, the overlap between two networks is
//! the mean cosine similarity of optimally matched filter pairs — 1.0 for
//! identical-up-to-permutation layers, ~0 for unrelated random filters.

use crate::align::assignment::{assignment_score, hungarian};

/// Cosine similarity of two filters.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na * nb)
}

/// Per-layer overlap after optimal filter matching.
///
/// `a`/`b` are the layer weights as `cout` rows of `filter_len` values
/// (the caller extracts rows from HWIO conv weights or dense columns).
pub fn layer_overlap(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return 1.0;
    }
    let score: Vec<Vec<f64>> = a
        .iter()
        .map(|fa| b.iter().map(|fb| cosine(fa, fb)).collect())
        .collect();
    let perm = hungarian(&score);
    assignment_score(&score, &perm) / n as f64
}

/// Overlap per layer across a whole network pair.
#[derive(Clone, Debug)]
pub struct OverlapReport {
    pub layers: Vec<(String, f64)>,
}

impl OverlapReport {
    pub fn mean(&self) -> f64 {
        if self.layers.is_empty() {
            return f64::NAN;
        }
        self.layers.iter().map(|(_, o)| o).sum::<f64>()
            / self.layers.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_filters(n: usize, d: usize, rng: &mut Pcg64) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| {
                let mut f = vec![0.0f32; d];
                rng.fill_normal(&mut f, 1.0);
                f
            })
            .collect()
    }

    #[test]
    fn identical_overlap_is_one() {
        let mut rng = Pcg64::new(1, 0);
        let a = random_filters(8, 16, &mut rng);
        assert!((layer_overlap(&a, &a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn permuted_copy_recovers_one() {
        let mut rng = Pcg64::new(2, 0);
        let a = random_filters(8, 16, &mut rng);
        let mut b = a.clone();
        b.rotate_left(3); // a permutation
        assert!((layer_overlap(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn random_pair_overlap_small() {
        let mut rng = Pcg64::new(3, 0);
        let a = random_filters(16, 64, &mut rng);
        let b = random_filters(16, 64, &mut rng);
        let o = layer_overlap(&a, &b);
        // matched random gaussian filters have small positive overlap
        assert!(o < 0.5, "overlap {o}");
        assert!(o > -0.2, "overlap {o}");
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }
}

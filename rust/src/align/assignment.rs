//! Assignment solvers for channel matching.
//!
//! `hungarian` is the exact O(n^3) Kuhn-Munkres algorithm (maximization
//! form) — channel counts here are <= a few hundred, so exact matching is
//! cheap. `greedy_assignment` is the paper's "greedy layer-wise matching"
//! baseline; tests verify hungarian >= greedy on total similarity.

/// Exact maximum-weight perfect matching on a square score matrix.
/// `score[i][j]` = similarity of A-channel i with B-channel j.
/// Returns `perm` with `perm[i] = j` (B-channel assigned to A-slot i).
pub fn hungarian(score: &[Vec<f64>]) -> Vec<usize> {
    let n = score.len();
    if n == 0 {
        return Vec::new();
    }
    // Kuhn-Munkres on cost = -score (minimization), potentials form.
    let inf = f64::INFINITY;
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to column j
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cost = -score[i0 - 1][j - 1];
                let cur = cost - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut perm = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            perm[p[j] - 1] = j - 1;
        }
    }
    perm
}

/// Greedy matching: repeatedly take the highest-scoring unmatched pair.
pub fn greedy_assignment(score: &[Vec<f64>]) -> Vec<usize> {
    let n = score.len();
    let mut pairs: Vec<(usize, usize, f64)> = Vec::with_capacity(n * n);
    for (i, row) in score.iter().enumerate() {
        for (j, &s) in row.iter().enumerate() {
            pairs.push((i, j, s));
        }
    }
    pairs.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    let mut perm = vec![usize::MAX; n];
    let mut used_j = vec![false; n];
    let mut assigned = 0;
    for (i, j, _) in pairs {
        if perm[i] == usize::MAX && !used_j[j] {
            perm[i] = j;
            used_j[j] = true;
            assigned += 1;
            if assigned == n {
                break;
            }
        }
    }
    perm
}

/// Total score of an assignment.
pub fn assignment_score(score: &[Vec<f64>], perm: &[usize]) -> f64 {
    perm.iter()
        .enumerate()
        .map(|(i, &j)| score[i][j])
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn identity_when_diagonal_dominates() {
        let score = vec![
            vec![9.0, 1.0, 0.0],
            vec![1.0, 8.0, 0.0],
            vec![0.0, 0.0, 7.0],
        ];
        assert_eq!(hungarian(&score), vec![0, 1, 2]);
        assert_eq!(greedy_assignment(&score), vec![0, 1, 2]);
    }

    #[test]
    fn finds_permuted_optimum() {
        // optimal is the anti-diagonal
        let score = vec![
            vec![0.0, 0.0, 5.0],
            vec![0.0, 5.0, 0.0],
            vec![5.0, 0.0, 0.0],
        ];
        assert_eq!(hungarian(&score), vec![2, 1, 0]);
    }

    #[test]
    fn hungarian_beats_greedy_trap() {
        // greedy takes (0,0)=10 then is forced into (1,1)=0;
        // optimal is (0,1)+(1,0) = 9+9
        let score = vec![vec![10.0, 9.0], vec![9.0, 0.0]];
        let h = hungarian(&score);
        let g = greedy_assignment(&score);
        assert!(assignment_score(&score, &h) >= assignment_score(&score, &g));
        assert_eq!(assignment_score(&score, &h), 18.0);
    }

    #[test]
    fn random_matrices_hungarian_is_optimal_vs_bruteforce() {
        let mut rng = Pcg64::new(11, 0);
        for _ in 0..20 {
            let n = 4;
            let score: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..n).map(|_| rng.next_f64()).collect())
                .collect();
            let h = assignment_score(&score, &hungarian(&score));
            // brute force over 4! permutations
            let mut best = f64::NEG_INFINITY;
            let mut perm = [0usize, 1, 2, 3];
            permute_all(&mut perm, 0, &mut |p| {
                let s: f64 =
                    p.iter().enumerate().map(|(i, &j)| score[i][j]).sum();
                if s > best {
                    best = s;
                }
            });
            assert!((h - best).abs() < 1e-9, "hungarian {h} vs brute {best}");
        }
    }

    fn permute_all(
        arr: &mut [usize; 4],
        k: usize,
        f: &mut impl FnMut(&[usize; 4]),
    ) {
        if k == 4 {
            f(arr);
            return;
        }
        for i in k..4 {
            arr.swap(k, i);
            permute_all(arr, k + 1, f);
            arr.swap(k, i);
        }
    }

    #[test]
    fn perms_are_valid() {
        let mut rng = Pcg64::new(5, 1);
        let n = 16;
        let score: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n).map(|_| rng.next_f64()).collect())
            .collect();
        for perm in [hungarian(&score), greedy_assignment(&score)] {
            let mut sorted = perm.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        }
    }
}

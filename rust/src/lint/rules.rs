//! The invariant rules.
//!
//! | rule | scope | invariant |
//! |------|-------|-----------|
//! | `D1` | reduce-path modules | no `HashMap`/`HashSet`, no `partial_cmp`, no float `sort_by` — reductions must be bit-exact and totally ordered |
//! | `D2` | whole tree | no truncating `as` casts on seed/replica identifiers — use `fold_seed_i32` / `try_into` |
//! | `A1` | `// lint: hot-path` regions | no steady-state allocation (`Vec::new`, `vec!`, `with_capacity`, `to_vec`, `.clone()`, `collect`) |
//! | `P1` | `// lint: panic-free` regions | no `.unwrap()`, `.expect()`, `panic!`-family macros, or slice indexing |
//! | `W1` | `wire.rs` / `codec.rs` / `checkpoint.rs` | every decoded length is cap-checked before it sizes an allocation |
//! | `S1` | `// lint: proto(STATE\|...)` regions | every wire tag mentioned is legal in the region's states per the `transport/protocol.rs` table, and every `match` on a frame tag handles exactly one direction's legal tag set |
//! | `R1` | `// lint: pooled` regions | a slab taken from a pool is recycled on every exit path — no `?`/`return` between take and release |
//! | `D3` | `// lint: deterministic` regions | no wall-clock or thread-identity reads (`Instant::now`, `SystemTime`, `thread::current()`) |
//!
//! S1 and R1 are function-level passes: they walk the marked region
//! spans from the brace-matched annotator rather than single tokens.
//! The S1 state-machine table is not duplicated here — it is parsed
//! out of `transport/protocol.rs` source by [`crate::lint::proto`], so
//! the spec and the check cannot drift.
//!
//! All rules skip `#[cfg(test)]` blocks and honor
//! `// lint: allow(RULE) -- reason` suppressions (see
//! [`crate::lint::annotate`]).

use std::collections::BTreeSet;

use crate::lint::annotate::{annotate, grammar_diagnostics, Annotated};
use crate::lint::proto::ProtoTable;
use crate::lint::report::Diagnostic;
use crate::lint::scanner::{scan, Tok, Token};

/// Modules on the bit-exact reduce path: rule D1 applies to files whose
/// path ends in one of these.
const REDUCE_PATH_MODULES: &[&str] = &[
    "coordinator/comm.rs",
    "opt/vecmath.rs",
    "coordinator/engine.rs",
    "coordinator/checkpoint.rs",
    "transport/wire.rs",
];

/// Files rule W1 applies to: the frame codec, the payload-transform
/// codec layered on top of it, and the checkpoint reader.
const WIRE_BOUND_FILES: &[&str] = &[
    "transport/wire.rs",
    "transport/codec.rs",
    "coordinator/checkpoint.rs",
];

/// Identifiers that prove a decoded length was cap-checked before the
/// allocation it sizes: the named caps, plus the shared readers that
/// perform the check internally.
const CAP_GUARDS: &[&str] = &[
    "MAX_FRAME",
    "MAX_PARAMS",
    "MAX_SECTIONS",
    "MAX_STR",
    "MAX_META",
    "read_payload_len",
    "read_flat_f32",
    "read_flat_f32_into",
    "read_flat_f64",
    "read_str",
];

/// Integer types an `as` cast can silently truncate a u64 seed or a
/// usize index into.
const NARROW_INTS: &[&str] = &["i8", "u8", "i16", "u16", "i32", "u32"];

/// Keywords that may directly precede `[` without it being an indexing
/// expression (`for x in [..]`, `return [..]`, ...).
const KEYWORDS_BEFORE_BRACKET: &[&str] = &[
    "in", "as", "if", "else", "match", "return", "break", "continue",
    "loop", "while", "for", "move", "ref", "mut", "let", "where",
    "unsafe", "dyn", "box", "await", "async", "yield", "static",
    "const", "impl", "use", "pub", "fn", "enum", "struct", "trait",
    "type", "mod",
];

/// Lint one source file with no protocol table in scope: any
/// `proto(...)` region is then an S1 error (the table is mandatory
/// context for protocol regions). Tree walks use [`lint_source_with`].
pub fn lint_source(file: &str, src: &str) -> Vec<Diagnostic> {
    lint_source_with(file, src, None)
}

/// Lint one source file (already read into `src`); `file` is the path
/// used in diagnostics and for path-scoped rules. `table` is the
/// protocol state machine parsed from `transport/protocol.rs`, if the
/// tree being linted contains one.
pub fn lint_source_with(
    file: &str,
    src: &str,
    table: Option<&ProtoTable>,
) -> Vec<Diagnostic> {
    let scanned = scan(src);
    let a = annotate(&scanned);
    let mut diags = grammar_diagnostics(&a, file);
    let norm = file.replace('\\', "/");
    if REDUCE_PATH_MODULES.iter().any(|m| norm.ends_with(m)) {
        rule_d1(file, &a, &mut diags);
    }
    rule_d2(file, &a, &mut diags);
    rule_a1(file, &a, &mut diags);
    rule_p1(file, &a, &mut diags);
    if WIRE_BOUND_FILES.iter().any(|m| norm.ends_with(m)) {
        rule_w1(file, &a, &mut diags);
    }
    rule_s1(file, &a, table, &mut diags);
    rule_r1(file, &a, &mut diags);
    rule_d3(file, &a, &mut diags);
    diags.sort_by(|x, y| (x.line, x.rule).cmp(&(y.line, y.rule)));
    diags
}

/// Count of `// lint: allow` suppressions in a file (for the
/// no-suppression gate on the fabric and transports).
pub fn suppression_count(src: &str) -> usize {
    let scanned = scan(src);
    annotate(&scanned).allow_count()
}

fn push(
    diags: &mut Vec<Diagnostic>,
    a: &Annotated,
    file: &str,
    rule: &'static str,
    t: &Token,
    msg: String,
) {
    if !a.allowed(rule, t.line) {
        diags.push(Diagnostic {
            file: file.to_string(),
            line: t.line,
            rule,
            msg,
        });
    }
}

/// Is token `i` live (outside `#[cfg(test)]` blocks)?
fn live(a: &Annotated, i: usize) -> bool {
    !a.in_test[i]
}

/// D1: determinism on the reduce path. Hash containers iterate in
/// seed-dependent order; `partial_cmp` is not a total order over
/// floats; a float `sort_by` without `total_cmp` is both. Reports are
/// sorted by replica id (`sort_by_key`) before any reduce — that
/// pattern stays legal.
fn rule_d1(file: &str, a: &Annotated, diags: &mut Vec<Diagnostic>) {
    let toks = a.tokens;
    for (i, t) in toks.iter().enumerate() {
        if !live(a, i) || t.kind != Tok::Ident {
            continue;
        }
        match t.text.as_str() {
            "HashMap" | "HashSet" => push(
                diags,
                a,
                file,
                "D1",
                t,
                format!(
                    "{} in a reduce-path module: iteration order is \
                     seed-dependent; use BTreeMap/BTreeSet or a \
                     replica-indexed Vec",
                    t.text
                ),
            ),
            "partial_cmp" => push(
                diags,
                a,
                file,
                "D1",
                t,
                "partial_cmp on the reduce path: not a total order \
                 over floats (NaN); use total_cmp or sort_by_key on \
                 an integer key"
                    .into(),
            ),
            "sort_by" | "sort_unstable_by" => {
                // sanctioned form: an explicit total_cmp comparator
                let uses_total_cmp = toks[i..]
                    .iter()
                    .take(20)
                    .any(|n| n.is_ident("total_cmp"));
                if !uses_total_cmp {
                    push(
                        diags,
                        a,
                        file,
                        "D1",
                        t,
                        format!(
                            "{} without total_cmp on the reduce path: \
                             float comparators must be a total order; \
                             sort_by_key(|r| r.replica) or total_cmp",
                            t.text
                        ),
                    );
                }
            }
            _ => {}
        }
    }
}

/// D2: seed/index hygiene. A plain `as i32`-style cast drops the high
/// bits of a u64 seed (runs differing only above bit 31 collapse) or
/// silently wraps an index; the sanctioned forms are
/// `crate::util::rng::fold_seed_i32` (keeps every seed bit
/// influential) and `try_into`/`try_from` (fails loudly).
fn rule_d2(file: &str, a: &Annotated, diags: &mut Vec<Diagnostic>) {
    let toks = a.tokens;
    for i in 0..toks.len() {
        if !live(a, i) {
            continue;
        }
        let t = &toks[i];
        if t.kind != Tok::Ident {
            continue;
        }
        let name = t.text.to_ascii_lowercase();
        if !(name.contains("seed") || name.contains("replica")) {
            continue;
        }
        let (Some(kw), Some(ty)) = (toks.get(i + 1), toks.get(i + 2))
        else {
            continue;
        };
        if kw.is_ident("as")
            && ty.kind == Tok::Ident
            && NARROW_INTS.contains(&ty.text.as_str())
        {
            push(
                diags,
                a,
                file,
                "D2",
                t,
                format!(
                    "truncating cast `{} as {}`: use fold_seed_i32 \
                     for seeds or try_into for indices",
                    t.text, ty.text
                ),
            );
        }
    }
}

/// A1: no allocation inside `// lint: hot-path` regions. The fabric's
/// steady state recycles every P-sized buffer (broadcast slabs via
/// `Arc::make_mut`, report slabs via the pool); an allocation here is
/// a regression the benches only catch as noise. `Arc::clone(&x)`
/// (refcount bump, no heap) stays legal — only the method-call form
/// `.clone()` is flagged.
fn rule_a1(file: &str, a: &Annotated, diags: &mut Vec<Diagnostic>) {
    let toks = a.tokens;
    for (i, t) in toks.iter().enumerate() {
        if !a.hot[i] || !live(a, i) || t.kind != Tok::Ident {
            continue;
        }
        let prev = i.checked_sub(1).map(|p| &toks[p]);
        let next = toks.get(i + 1);
        let flagged = match t.text.as_str() {
            "vec" => next.is_some_and(|n| n.is_punct('!')),
            "Vec" => {
                // Vec::new (with_capacity is caught by its own ident
                // below, covering both Vec:: and method-call forms)
                toks.get(i + 3).is_some_and(|m| {
                    toks[i + 1].is_punct(':')
                        && toks[i + 2].is_punct(':')
                        && m.is_ident("new")
                })
            }
            "to_vec" | "collect" | "with_capacity" => true,
            "clone" => prev.is_some_and(|p| p.is_punct('.')),
            _ => false,
        };
        if flagged {
            push(
                diags,
                a,
                file,
                "A1",
                t,
                format!(
                    "`{}` allocates inside a hot-path region: recycle \
                     a slab, write through Arc::make_mut, or hoist the \
                     warmup allocation into a cold helper",
                    t.text
                ),
            );
        }
    }
}

/// P1: no panics inside `// lint: panic-free` regions (worker bodies,
/// TCP reader threads, the master's event-loop receive). A panic there
/// tears down a thread whose death the fabric only learns about as a
/// hang — errors must flow as `FabricEvent::Failed`/`Exited` instead.
fn rule_p1(file: &str, a: &Annotated, diags: &mut Vec<Diagnostic>) {
    let toks = a.tokens;
    for (i, t) in toks.iter().enumerate() {
        if !a.panic_free[i] || !live(a, i) {
            continue;
        }
        let prev = i.checked_sub(1).map(|p| &toks[p]);
        let next = toks.get(i + 1);
        if t.kind == Tok::Ident {
            let flagged = match t.text.as_str() {
                "unwrap" | "expect" => {
                    prev.is_some_and(|p| p.is_punct('.'))
                }
                "panic" | "unreachable" | "todo" | "unimplemented" => {
                    next.is_some_and(|n| n.is_punct('!'))
                }
                _ => false,
            };
            if flagged {
                push(
                    diags,
                    a,
                    file,
                    "P1",
                    t,
                    format!(
                        "`{}` inside a panic-free region: propagate an \
                         error (bail!/Context) so the fabric surfaces \
                         Failed/Exited instead of hanging",
                        t.text
                    ),
                );
            }
        } else if t.kind == Tok::Punct('[') {
            // indexing expression: `[` directly after a value (ident
            // that is not a keyword, `]`, or `)`) can panic; array
            // literals / attributes / macros are preceded by
            // punctuation and stay legal
            let is_indexing = match prev {
                Some(p) if p.kind == Tok::Ident => {
                    !KEYWORDS_BEFORE_BRACKET
                        .contains(&p.text.as_str())
                }
                Some(p) => p.is_punct(']') || p.is_punct(')'),
                None => false,
            };
            if is_indexing {
                push(
                    diags,
                    a,
                    file,
                    "P1",
                    t,
                    "slice indexing inside a panic-free region: use \
                     .get()/.get_mut() and propagate the miss as an \
                     error"
                        .into(),
                );
            }
        }
    }
}

/// W1: every wire/checkpoint-decoded length must pass a named cap
/// before it sizes an allocation. Applies to decode-side functions
/// (`read_*`, `decode_*`, `load`, `try_read_*`) in `wire.rs` and
/// `checkpoint.rs`: a dynamically-sized `vec!`/`with_capacity`/
/// `reserve` there must be preceded, within the same function, by one
/// of the shared caps or cap-checking readers ([`CAP_GUARDS`]).
fn rule_w1(file: &str, a: &Annotated, diags: &mut Vec<Diagnostic>) {
    let toks = a.tokens;
    for (i, t) in toks.iter().enumerate() {
        if !live(a, i) || t.kind != Tok::Ident {
            continue;
        }
        let dynamic = match t.text.as_str() {
            "vec" => toks.get(i + 1).is_some_and(|n| n.is_punct('!'))
                && vec_macro_len_is_dynamic(toks, i),
            "with_capacity" | "reserve" => {
                call_args_have_ident(toks, i + 1)
            }
            _ => false,
        };
        if !dynamic {
            continue;
        }
        let Some(fn_start) = enclosing_fn(toks, i) else {
            continue;
        };
        let fn_name = toks
            .get(fn_start + 1)
            .filter(|n| n.kind == Tok::Ident)
            .map(|n| n.text.as_str())
            .unwrap_or("");
        let decode_side = fn_name.starts_with("read_")
            || fn_name.starts_with("decode_")
            || fn_name.starts_with("try_read_")
            || fn_name == "load";
        if !decode_side {
            continue;
        }
        let guarded = toks[fn_start..i].iter().any(|g| {
            g.kind == Tok::Ident
                && CAP_GUARDS.contains(&g.text.as_str())
        });
        if !guarded {
            push(
                diags,
                a,
                file,
                "W1",
                t,
                format!(
                    "dynamically-sized allocation in `{fn_name}` with \
                     no cap check: validate the decoded length against \
                     a shared MAX_* cap (or read through \
                     read_payload_len) before allocating"
                ),
            );
        }
    }
}

/// For `vec!` at token `i`: does the repeat-length / element list
/// contain an identifier (i.e. a runtime-sized allocation)?
fn vec_macro_len_is_dynamic(toks: &[Token], i: usize) -> bool {
    // vec! [ elem ; len ] or vec! ( ... ) — scan the bracketed group
    let Some(open) = toks.get(i + 2) else {
        return false;
    };
    let (open_c, close_c) = match open.kind {
        Tok::Punct('[') => ('[', ']'),
        Tok::Punct('(') => ('(', ')'),
        Tok::Punct('{') => ('{', '}'),
        _ => return false,
    };
    let mut depth = 0i32;
    for t in &toks[i + 2..] {
        match t.kind {
            Tok::Punct(c) if c == open_c => depth += 1,
            Tok::Punct(c) if c == close_c => {
                depth -= 1;
                if depth == 0 {
                    return false;
                }
            }
            Tok::Ident if depth >= 1 => {
                // suffixed literals (`0.0f32`) lex as Num, so any
                // ident in the macro body means a runtime size/value
                return true;
            }
            _ => {}
        }
    }
    false
}

/// For `with_capacity`/`reserve` at token `i`, `open_at = i + 1`: does
/// the argument list contain an identifier?
fn call_args_have_ident(toks: &[Token], open_at: usize) -> bool {
    if !toks.get(open_at).is_some_and(|t| t.is_punct('(')) {
        return false;
    }
    let mut depth = 0i32;
    for t in &toks[open_at..] {
        match t.kind {
            Tok::Punct('(') => depth += 1,
            Tok::Punct(')') => {
                depth -= 1;
                if depth == 0 {
                    return false;
                }
            }
            Tok::Ident if depth >= 1 => return true,
            _ => {}
        }
    }
    false
}

/// Index of the nearest preceding `fn` keyword (the enclosing function
/// item, to a close-enough approximation for a token linter).
fn enclosing_fn(toks: &[Token], i: usize) -> Option<usize> {
    toks[..i].iter().rposition(|t| t.is_ident("fn"))
}

/// Report a region-level diagnostic at a specific line, honoring
/// suppressions.
fn push_at(
    diags: &mut Vec<Diagnostic>,
    a: &Annotated,
    file: &str,
    rule: &'static str,
    line: u32,
    msg: String,
) {
    if !a.allowed(rule, line) {
        diags.push(Diagnostic {
            file: file.to_string(),
            line,
            rule,
            msg,
        });
    }
}

/// S1: protocol conformance. Inside a `// lint: proto(STATE|...)`
/// region, (a) every `wire::TAG_*` identifier must be a tag the
/// protocol table allows in at least one of the region's states
/// (either direction — a region is one endpoint's view of those
/// states), and (b) every `match` whose scrutinee is a frame tag
/// (`match frame.tag { ... }`) must pattern-match **exactly** the tag
/// set one direction allows across the region's states: a missing arm
/// is an unhandled legal message, a surplus arm is a message this
/// endpoint can never legally see. Wildcard/binding fallback arms stay
/// legal — that is where illegal tags become typed errors.
fn rule_s1(
    file: &str,
    a: &Annotated,
    table: Option<&ProtoTable>,
    diags: &mut Vec<Diagnostic>,
) {
    let toks = a.tokens;
    for region in &a.proto_regions {
        if a.in_test[region.open] {
            continue;
        }
        let Some(table) = table else {
            push_at(
                diags,
                a,
                file,
                "S1",
                region.line,
                "proto(...) region with no protocol table in scope: \
                 the linted tree must include \
                 coordinator/transport/protocol.rs"
                    .into(),
            );
            continue;
        };
        let mut states_ok = true;
        for s in &region.states {
            if !table.has_state(s) {
                states_ok = false;
                push_at(
                    diags,
                    a,
                    file,
                    "S1",
                    region.line,
                    format!(
                        "proto({s}) names a state the protocol table \
                         does not define"
                    ),
                );
            }
        }
        if !states_ok {
            continue;
        }
        let legal_any = table.tags_in(&region.states);
        let here = region.states.join("|");
        // (a) soundness: every tag the region mentions must be legal
        for i in region.open..=region.close {
            let t = &toks[i];
            if !live(a, i)
                || t.kind != Tok::Ident
                || !t.text.starts_with("TAG_")
            {
                continue;
            }
            if !legal_any.contains(&t.text) {
                let in_fn = a
                    .enclosing_fn_name(i)
                    .map(|f| format!(" (in fn {f})"))
                    .unwrap_or_default();
                push(
                    diags,
                    a,
                    file,
                    "S1",
                    t,
                    format!(
                        "`{}` is illegal in protocol state(s) {here}: \
                         the table allows {}{}",
                        t.text,
                        join_tags(&legal_any),
                        in_fn
                    ),
                );
            }
        }
        // (b) exactness of frame-tag dispatch sites
        for m in region.open..=region.close {
            if !live(a, m) || !toks[m].is_ident("match") {
                continue;
            }
            let Some((body_open, arms)) = tag_match_at(a, m) else {
                continue;
            };
            let to_worker =
                table.tags_in_dir(&region.states, "ToWorker");
            let to_master =
                table.tags_in_dir(&region.states, "ToMaster");
            let expected = if arms.is_subset(&to_worker) {
                &to_worker
            } else if arms.is_subset(&to_master) {
                &to_master
            } else {
                push(
                    diags,
                    a,
                    file,
                    "S1",
                    &toks[m],
                    format!(
                        "frame-tag match mixes directions in state(s) \
                         {here}: arms {} fit neither the to-worker set \
                         {} nor the to-master set {}",
                        join_tags(&arms),
                        join_tags(&to_worker),
                        join_tags(&to_master)
                    ),
                );
                continue;
            };
            for missing in expected.difference(&arms) {
                push(
                    diags,
                    a,
                    file,
                    "S1",
                    &toks[body_open],
                    format!(
                        "frame-tag match does not handle `{missing}`, \
                         which is legal in state(s) {here}"
                    ),
                );
            }
        }
    }
}

/// If the `match` at token `m` dispatches on a frame tag (scrutinee
/// ends `.tag` or is a `tag` binding), return its body-`{` index and
/// the set of `TAG_*` idents used as arm patterns (tokens between the
/// body start / an arm separator and the arm's `=>`).
fn tag_match_at(
    a: &Annotated,
    m: usize,
) -> Option<(usize, BTreeSet<String>)> {
    let toks = a.tokens;
    // scrutinee: tokens up to the match's own `{`
    let mut j = m + 1;
    let body_open = loop {
        match toks.get(j) {
            Some(t) if t.is_punct('{') => break j,
            Some(t) if t.is_punct(';') => return None,
            Some(_) => j += 1,
            None => return None,
        }
    };
    let dispatches_on_tag = toks[m + 1..body_open]
        .last()
        .is_some_and(|t| t.is_ident("tag"));
    if !dispatches_on_tag {
        return None;
    }
    let body_close = (*a.matching.get(body_open)?)?;
    let mut arms = BTreeSet::new();
    let mut depth = 0i32;
    let mut in_pattern = true;
    for t in &toks[body_open + 1..body_close] {
        match t.kind {
            Tok::Punct('{') | Tok::Punct('(') | Tok::Punct('[') => {
                depth += 1
            }
            Tok::Punct('}') => {
                depth -= 1;
                // a block arm body ended: next tokens open a pattern
                if depth == 0 {
                    in_pattern = true;
                }
            }
            Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
            // `=>` terminates the pattern (the `>` is a separate punct
            // token; flipping on `=` alone is fine since a bare `=`
            // cannot appear in a pattern at depth 0)
            Tok::Punct('=') if depth == 0 => in_pattern = false,
            Tok::Punct(',') if depth == 0 => in_pattern = true,
            Tok::Ident
                if in_pattern
                    && depth == 0
                    && t.text.starts_with("TAG_") =>
            {
                arms.insert(t.text.clone());
            }
            _ => {}
        }
    }
    Some((body_open, arms))
}

fn join_tags(set: &BTreeSet<String>) -> String {
    if set.is_empty() {
        "nothing".to_string()
    } else {
        set.iter()
            .map(String::as_str)
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Identifiers that take a slab out of a pool inside `pooled` regions
/// (method-call position: preceded by `.`).
const POOL_TAKES: &[&str] = &["take", "drain"];

/// Identifiers that hand a taken slab on to an owner that recycles it:
/// the wire send (`send_cmd`), wrapping it into the round message that
/// the receiver recycles (`RoundMsg`), and the pool itself
/// (`recycle`, `slab_pool`, `push`).
const POOL_RELEASES: &[&str] =
    &["send_cmd", "RoundMsg", "recycle", "slab_pool", "push"];

/// R1: pool discipline. Inside a `// lint: pooled` region, once a slab
/// is taken (`.take()` / `.drain()`), every exit path must hand it
/// back before leaving: a `?` or `return` while holding can leak the
/// slab out of the pool (the steady state then allocates — the class
/// of leak A1 cannot see, because the allocation happens rounds
/// later). Reaching the end of the region still holding is the same
/// leak.
fn rule_r1(file: &str, a: &Annotated, diags: &mut Vec<Diagnostic>) {
    let toks = a.tokens;
    for region in &a.pooled_regions {
        if a.in_test[region.open] {
            continue;
        }
        let mut holding: Option<usize> = None;
        for i in region.open + 1..region.close {
            if !live(a, i) {
                continue;
            }
            let t = &toks[i];
            match t.kind {
                Tok::Ident
                    if POOL_TAKES.contains(&t.text.as_str())
                        && i > 0
                        && toks[i - 1].is_punct('.') =>
                {
                    holding = Some(i);
                }
                Tok::Ident
                    if POOL_RELEASES.contains(&t.text.as_str()) =>
                {
                    holding = None;
                }
                Tok::Punct('?') if holding.is_some() => {
                    let taken = &toks[holding.unwrap_or(i)];
                    push(
                        diags,
                        a,
                        file,
                        "R1",
                        t,
                        format!(
                            "`?` while holding the slab taken on line \
                             {}: an error here leaks it out of the \
                             pool; recycle (or stash) before \
                             propagating",
                            taken.line
                        ),
                    );
                }
                Tok::Ident
                    if t.text == "return" && holding.is_some() =>
                {
                    let taken = &toks[holding.unwrap_or(i)];
                    push(
                        diags,
                        a,
                        file,
                        "R1",
                        t,
                        format!(
                            "early return while holding the slab taken \
                             on line {}: recycle it before leaving the \
                             pooled region",
                            taken.line
                        ),
                    );
                }
                _ => {}
            }
        }
        if let Some(at) = holding {
            push(
                diags,
                a,
                file,
                "R1",
                &toks[at],
                "slab taken from the pool is never handed back inside \
                 this pooled region"
                    .to_string(),
            );
        }
    }
}

/// D3: no wall-clock or thread-identity reads inside
/// `// lint: deterministic` regions. `Instant::now`/`SystemTime`
/// values that leak into reduce-path arithmetic make runs
/// unreproducible in a way D1's container/ordering checks cannot see;
/// `thread::current()` identity has the same property under work
/// stealing. Timing belongs in the profiler, outside these regions.
fn rule_d3(file: &str, a: &Annotated, diags: &mut Vec<Diagnostic>) {
    let toks = a.tokens;
    for (i, t) in toks.iter().enumerate() {
        if !a.deterministic[i] || !live(a, i) || t.kind != Tok::Ident {
            continue;
        }
        let flagged = match t.text.as_str() {
            "SystemTime" => true,
            "Instant" => {
                toks.get(i + 1).is_some_and(|x| x.is_punct(':'))
                    && toks.get(i + 2).is_some_and(|x| x.is_punct(':'))
                    && toks.get(i + 3).is_some_and(|x| x.is_ident("now"))
            }
            "current" => {
                i >= 3
                    && toks[i - 1].is_punct(':')
                    && toks[i - 2].is_punct(':')
                    && toks[i - 3].is_ident("thread")
            }
            _ => false,
        };
        if flagged {
            push(
                diags,
                a,
                file,
                "D3",
                t,
                format!(
                    "`{}` inside a deterministic region: wall-clock / \
                     thread-identity reads must not influence \
                     reduce-path values; time belongs in the profiler \
                     outside this region",
                    t.text
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(file: &str, src: &str) -> Vec<&'static str> {
        lint_source(file, src).iter().map(|d| d.rule).collect()
    }

    #[test]
    fn d1_only_fires_on_reduce_path_modules() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules_hit("src/coordinator/comm.rs", src), vec!["D1"]);
        assert!(rules_hit("src/experiments/fig1.rs", src).is_empty());
    }

    #[test]
    fn d1_sort_by_with_total_cmp_is_sanctioned() {
        let flagged = "fn f(v: &mut Vec<f32>) { v.sort_by(|a, b| a.cmp(b)); }";
        let sanctioned =
            "fn f(v: &mut Vec<f32>) { v.sort_by(|a, b| a.total_cmp(b)); }";
        let keyed = "fn f(v: &mut Vec<R>) { v.sort_by_key(|r| r.replica); }";
        assert_eq!(rules_hit("opt/vecmath.rs", flagged), vec!["D1"]);
        assert!(rules_hit("opt/vecmath.rs", sanctioned).is_empty());
        assert!(rules_hit("opt/vecmath.rs", keyed).is_empty());
    }

    #[test]
    fn d2_flags_truncating_seed_and_replica_casts() {
        assert_eq!(
            rules_hit("src/x.rs", "let s = seed as i32;"),
            vec!["D2"]
        );
        assert_eq!(
            rules_hit("src/x.rs", "let r = rep.replica as u32;"),
            vec!["D2"]
        );
        // widening casts and unrelated identifiers stay legal
        assert!(rules_hit("src/x.rs", "let s = seed as u64;").is_empty());
        assert!(rules_hit("src/x.rs", "let s = step as i32;").is_empty());
        // the sanctioned fold: the cast operand is an expression, not
        // the bare seed
        assert!(rules_hit(
            "src/x.rs",
            "let s = (((seed >> 32) ^ seed) as u32) as i32;"
        )
        .is_empty());
    }

    #[test]
    fn a1_fires_only_inside_hot_regions() {
        let cold = "fn f() { let v = vec![0.0f32; p]; }";
        assert!(rules_hit("src/x.rs", cold).is_empty());
        let hot = "\
fn f() {
    // lint: hot-path
    {
        let v = vec![0.0f32; p];
        let w = Vec::with_capacity(p);
        let c = x.clone();
        let s = y.to_vec();
        let z: Vec<f32> = it.collect();
    }
}
";
        assert_eq!(
            rules_hit("src/x.rs", hot),
            vec!["A1", "A1", "A1", "A1", "A1"]
        );
    }

    #[test]
    fn a1_arc_clone_form_is_sanctioned() {
        let src = "\
fn f() {
    // lint: hot-path
    {
        let x = Arc::clone(&slab);
        let s = pool.take().unwrap_or_default();
    }
}
";
        assert!(rules_hit("src/x.rs", src).is_empty());
    }

    #[test]
    fn p1_fires_on_panics_and_indexing_in_regions() {
        let src = "\
fn f() {
    // lint: panic-free
    {
        let a = x.unwrap();
        let b = y.expect(\"msg\");
        panic!(\"boom\");
        let c = v[i];
        let d = v.get(i);
        let e = other.unwrap_or(0);
        for q in [1, 2] { let _ = q; }
    }
}
";
        assert_eq!(rules_hit("src/x.rs", src), vec!["P1", "P1", "P1", "P1"]);
    }

    #[test]
    fn w1_requires_a_cap_before_dynamic_decode_allocations() {
        let bad = "\
fn decode_thing(p: &[u8]) -> Vec<u8> {
    let len = read_len(p);
    vec![0u8; len]
}
";
        let good = "\
fn decode_thing(p: &[u8]) -> Vec<u8> {
    let len = read_len(p);
    if len > MAX_FRAME as usize { return Vec::new(); }
    vec![0u8; len]
}
";
        let encode_side = "\
fn encode_thing(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    out
}
";
        assert_eq!(rules_hit("transport/wire.rs", bad), vec!["W1"]);
        assert!(rules_hit("transport/wire.rs", good).is_empty());
        assert!(rules_hit("transport/wire.rs", encode_side).is_empty());
        // literal-sized allocations never need a cap
        let literal = "fn read_hdr() -> Vec<u8> { vec![0u8; 8] }";
        assert!(rules_hit("transport/wire.rs", literal).is_empty());
        // and the rule only runs in the codec files
        assert!(rules_hit("src/other.rs", bad).is_empty());
    }

    #[test]
    fn allows_suppress_exactly_their_rule_and_line() {
        let src = "\
fn f() {
    // lint: panic-free
    {
        // lint: allow(P1) -- checked two lines up, cannot be None
        let a = x.unwrap();
        let b = y.unwrap();
    }
}
";
        let diags = lint_source("src/x.rs", src);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].line, 6);
        assert_eq!(suppression_count(src), 1);
    }

    #[test]
    fn cfg_test_blocks_are_exempt() {
        let src = "\
// lint: panic-free
fn f() { good(); }
#[cfg(test)]
mod tests {
    fn t() { let x = opt.unwrap(); let m = std::collections::HashMap::new(); }
}
";
        assert!(lint_source("src/coordinator/comm.rs", src).is_empty());
    }

    #[test]
    fn grammar_errors_surface_as_lint_diagnostics() {
        let src = "// lint: allow(A1)\nfn f() {}\n";
        let diags = lint_source("src/x.rs", src);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "LINT");
        assert!(diags[0].msg.contains("reason"));
    }

    fn mini_table() -> ProtoTable {
        crate::lint::proto::parse_table(
            "pub const TRANSITIONS: &[(State, Dir, u8, State)] = &[\n\
             (State::Hello, Dir::ToMaster, wire::TAG_HELLO, State::Run),\n\
             (State::Run, Dir::ToWorker, wire::TAG_ROUND, State::Busy),\n\
             (State::Busy, Dir::ToMaster, wire::TAG_REPORT, State::Run),\n\
             (State::Run, Dir::ToWorker, wire::TAG_STOP, State::Done),\n\
             ];",
        )
        .unwrap()
    }

    fn rules_hit_with(
        file: &str,
        src: &str,
        table: &ProtoTable,
    ) -> Vec<&'static str> {
        lint_source_with(file, src, Some(table))
            .iter()
            .map(|d| d.rule)
            .collect()
    }

    #[test]
    fn s1_flags_tags_illegal_in_the_region_states() {
        let table = mini_table();
        let bad = "\
fn f(w: &mut W) {
    // lint: proto(Hello)
    {
        w.send(TAG_ROUND);
    }
}
";
        assert_eq!(rules_hit_with("src/t.rs", bad, &table), vec!["S1"]);
        let good = "\
fn f(w: &mut W) {
    // lint: proto(Hello)
    {
        w.send(TAG_HELLO);
    }
}
";
        assert!(rules_hit_with("src/t.rs", good, &table).is_empty());
    }

    #[test]
    fn s1_requires_tag_matches_to_be_exact() {
        let table = mini_table();
        // Run's to-worker set is {ROUND, STOP}: a dispatch missing
        // STOP leaves a legal message unhandled
        let missing = "\
fn recv(frame: Frame) {
    // lint: proto(Run)
    {
        match frame.tag {
            TAG_ROUND => round(),
            other => bail(other),
        }
    }
}
";
        let diags =
            lint_source_with("src/t.rs", missing, Some(&table));
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "S1");
        assert!(diags[0].msg.contains("TAG_STOP"));
        let exact = "\
fn recv(frame: Frame) {
    // lint: proto(Run)
    {
        match frame.tag {
            TAG_ROUND => round(),
            TAG_STOP => stop(),
            other => bail(other),
        }
    }
}
";
        assert!(rules_hit_with("src/t.rs", exact, &table).is_empty());
        // an arm from the wrong direction can fit neither set
        let mixed = "\
fn recv(frame: Frame) {
    // lint: proto(Run)
    {
        match frame.tag {
            TAG_ROUND => round(),
            TAG_REPORT => report(),
            other => bail(other),
        }
    }
}
";
        let diags = lint_source_with("src/t.rs", mixed, Some(&table));
        assert!(diags.iter().any(|d| d.rule == "S1"
            && d.msg.contains("mixes directions")));
    }

    #[test]
    fn s1_errors_on_unknown_states_and_missing_table() {
        let table = mini_table();
        let unknown = "\
fn f() {
    // lint: proto(Warp)
    {
        g();
    }
}
";
        let diags =
            lint_source_with("src/t.rs", unknown, Some(&table));
        assert_eq!(diags.len(), 1);
        assert!(diags[0].msg.contains("does not define"));
        // the plain entry point has no table: proto regions then error
        let diags = lint_source("src/t.rs", unknown);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].msg.contains("no protocol table"));
    }

    #[test]
    fn r1_flags_question_marks_and_returns_while_holding() {
        let leaky = "\
fn send(&mut self) -> Result<()> {
    // lint: pooled
    {
        let mut slab = self.pool.take();
        encode_into(&mut slab)?;
        self.transport.send_cmd(0, slab);
    }
    Ok(())
}
";
        let diags = lint_source("src/t.rs", leaky);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "R1");
        assert!(diags[0].msg.contains('?'));
        let early = "\
fn send(&mut self) -> Result<()> {
    // lint: pooled
    {
        let slab = self.pool.take();
        if bad() { return Err(anyhow(\"no\")); }
        self.transport.send_cmd(0, slab);
    }
    Ok(())
}
";
        let diags = lint_source("src/t.rs", early);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "R1");
        assert!(diags[0].msg.contains("return"));
    }

    #[test]
    fn r1_clean_paths_and_end_of_region_leaks() {
        let clean = "\
fn send(&mut self) -> Result<()> {
    // lint: pooled
    {
        fallible()?;
        let mut slab = self.pool.take();
        encode_into(&mut slab);
        self.transport.send_cmd(0, slab);
    }
    Ok(())
}
";
        assert!(lint_source("src/t.rs", clean).is_empty());
        let lost = "\
fn send(&mut self) {
    // lint: pooled
    {
        let slab = self.pool.take();
        sink(slab);
    }
}
";
        let diags = lint_source("src/t.rs", lost);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "R1");
        assert!(diags[0].msg.contains("never handed back"));
    }

    #[test]
    fn d3_flags_clock_and_thread_identity_in_regions() {
        let src = "\
fn reduce(&mut self) {
    // lint: deterministic
    {
        let t = Instant::now();
        let s = SystemTime::now();
        let id = thread::current().id();
    }
    let outside = Instant::now();
}
";
        assert_eq!(
            rules_hit("src/t.rs", src),
            vec!["D3", "D3", "D3"]
        );
        // mentioning the types without reading a clock stays legal
        let typed = "\
fn reduce(&mut self, started: Instant) {
    // lint: deterministic
    {
        let x = elapsed_of(started);
    }
}
";
        assert!(rules_hit("src/t.rs", typed).is_empty());
    }
}

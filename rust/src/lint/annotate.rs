//! The `// lint:` annotation grammar and the token-region machinery
//! built on it.
//!
//! Three directives:
//!
//! * `// lint: hot-path [-- note]` — marks the next `{ ... }` block as
//!   a steady-state region: rule **A1** forbids allocation inside it.
//! * `// lint: panic-free [-- note]` — marks the next block as a
//!   region where rule **P1** forbids `unwrap`/`expect`/`panic!` and
//!   slice indexing (a panic there poisons the shared fabric event
//!   stream instead of surfacing `Exited`/`Failed`).
//! * `// lint: allow(RULE) -- reason` — suppresses RULE on the
//!   directive's line and the next code line. The reason is
//!   **mandatory**: an unexplained suppression is itself a violation.
//!
//! Anything else after `// lint:` is an error — the directive channel
//! stays small enough to audit by eye.

use crate::lint::report::Diagnostic;
use crate::lint::scanner::{Directive, Scan, Tok, Token};

/// Rule names the annotation grammar accepts in `allow(...)`.
pub const RULES: &[&str] = &["D1", "D2", "A1", "P1", "W1"];

/// A parsed directive.
#[derive(Clone, Debug, PartialEq)]
pub enum DirectiveKind {
    HotPath,
    PanicFree,
    Allow { rule: String },
}

/// Parse one directive body (the text after `// lint:`).
pub fn parse_directive(text: &str) -> Result<DirectiveKind, String> {
    let (head, note) = match text.split_once("--") {
        Some((h, n)) => (h.trim(), Some(n.trim())),
        None => (text.trim(), None),
    };
    if let Some(rest) = head.strip_prefix("allow(") {
        let Some(rule) = rest.strip_suffix(')').map(str::trim) else {
            return Err(format!("unclosed allow(...) in {text:?}"));
        };
        if !RULES.contains(&rule) {
            return Err(format!(
                "unknown rule {rule:?} in allow (rules: {})",
                RULES.join(", ")
            ));
        }
        match note {
            Some(r) if !r.is_empty() => Ok(DirectiveKind::Allow {
                rule: rule.to_string(),
            }),
            _ => Err(format!(
                "allow({rule}) needs a reason: \
                 `// lint: allow({rule}) -- why this is sound`"
            )),
        }
    } else {
        match head {
            "hot-path" => Ok(DirectiveKind::HotPath),
            "panic-free" => Ok(DirectiveKind::PanicFree),
            other => Err(format!(
                "unknown lint directive {other:?} \
                 (hot-path, panic-free, allow(RULE) -- reason)"
            )),
        }
    }
}

/// Everything rules need besides the raw tokens: brace matching, the
/// `#[cfg(test)] mod` mask, marked regions and the allow table.
pub struct Annotated<'a> {
    pub tokens: &'a [Token],
    /// `in_test[i]` — token i sits inside a `#[cfg(test)] mod` block.
    pub in_test: Vec<bool>,
    /// `hot[i]` — token i sits inside a `// lint: hot-path` block.
    pub hot: Vec<bool>,
    /// `panic_free[i]` — token i sits inside a `// lint: panic-free`
    /// block.
    pub panic_free: Vec<bool>,
    /// (rule, line) pairs with an active `allow`.
    allows: Vec<(String, u32)>,
    /// Number of `allow` directives (each expands to two `allows`
    /// entries: its own line and the next code line).
    allow_directives: usize,
    /// Grammar errors to surface as diagnostics.
    pub errors: Vec<(u32, String)>,
}

impl<'a> Annotated<'a> {
    /// Is `rule` suppressed on `line`?
    pub fn allowed(&self, rule: &str, line: u32) -> bool {
        self.allows
            .iter()
            .any(|(r, l)| r == rule && line == *l)
    }

    /// Number of `allow` directives in the file (any rule).
    pub fn allow_count(&self) -> usize {
        self.allow_directives
    }
}

/// Build the [`Annotated`] view of a scan.
pub fn annotate<'a>(scan: &'a Scan) -> Annotated<'a> {
    let tokens = &scan.tokens;
    let matching = match_braces(tokens);
    let mut a = Annotated {
        tokens,
        in_test: test_mask(tokens, &matching),
        hot: vec![false; tokens.len()],
        panic_free: vec![false; tokens.len()],
        allows: Vec::new(),
        allow_directives: 0,
        errors: Vec::new(),
    };
    for d in &scan.directives {
        match parse_directive(&d.text) {
            Ok(DirectiveKind::HotPath) => {
                mark_next_block(tokens, &matching, d, &mut a.hot)
                    .unwrap_or_else(|e| a.errors.push((d.line, e)));
            }
            Ok(DirectiveKind::PanicFree) => {
                mark_next_block(tokens, &matching, d, &mut a.panic_free)
                    .unwrap_or_else(|e| a.errors.push((d.line, e)));
            }
            Ok(DirectiveKind::Allow { rule }) => {
                // the directive's own line plus the next code line, so
                // the annotation can sit above the statement it excuses
                a.allow_directives += 1;
                a.allows.push((rule.clone(), d.line));
                if let Some(next) = tokens
                    .iter()
                    .map(|t| t.line)
                    .filter(|&l| l > d.line)
                    .min()
                {
                    a.allows.push((rule, next));
                }
            }
            Err(e) => a.errors.push((d.line, e)),
        }
    }
    a
}

/// `matching[i] = Some(j)` for brace tokens, pairing `{`...`}`.
fn match_braces(tokens: &[Token]) -> Vec<Option<usize>> {
    let mut matching = vec![None; tokens.len()];
    let mut stack = Vec::new();
    for (i, t) in tokens.iter().enumerate() {
        if t.is_punct('{') {
            stack.push(i);
        } else if t.is_punct('}') {
            if let Some(open) = stack.pop() {
                matching[open] = Some(i);
                matching[i] = Some(open);
            }
        }
    }
    matching
}

/// Mark the tokens of `#[cfg(test)] mod <name> { ... }` blocks (and
/// any other `#[cfg(test)]`-attributed braced item). Test code is
/// exempt from the steady-state rules — it is allowed to allocate,
/// unwrap and index.
fn test_mask(tokens: &[Token], matching: &[Option<usize>]) -> Vec<bool> {
    let mut mask = vec![false; tokens.len()];
    let mut i = 0usize;
    while i < tokens.len() {
        if is_cfg_test_at(tokens, i) {
            // find the first `{` after the attribute and mask its block
            let mut j = i;
            while j < tokens.len() && !tokens[j].is_punct('{') {
                j += 1;
            }
            if let Some(Some(close)) = matching.get(j) {
                for slot in &mut mask[j..=*close] {
                    *slot = true;
                }
                i = *close + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// Does `#` `[` `cfg` `(` `test` `)` `]` start at token `i`?
fn is_cfg_test_at(tokens: &[Token], i: usize) -> bool {
    let pat: &[&dyn Fn(&Token) -> bool] = &[
        &|t: &Token| t.is_punct('#'),
        &|t: &Token| t.is_punct('['),
        &|t: &Token| t.is_ident("cfg"),
        &|t: &Token| t.is_punct('('),
        &|t: &Token| t.is_ident("test"),
        &|t: &Token| t.is_punct(')'),
        &|t: &Token| t.is_punct(']'),
    ];
    tokens.len() >= i + pat.len()
        && pat
            .iter()
            .zip(&tokens[i..])
            .all(|(p, t)| p(t))
}

/// Mark the block opened by the first `{` at or after the directive's
/// line.
fn mark_next_block(
    tokens: &[Token],
    matching: &[Option<usize>],
    d: &Directive,
    mask: &mut [bool],
) -> Result<(), String> {
    let open = tokens
        .iter()
        .position(|t| t.is_punct('{') && t.line >= d.line)
        .ok_or_else(|| {
            format!("no `{{` block follows the directive {:?}", d.text)
        })?;
    let close = matching[open]
        .ok_or_else(|| format!("unbalanced block after {:?}", d.text))?;
    for slot in &mut mask[open..=close] {
        *slot = true;
    }
    Ok(())
}

/// Turn this file's grammar errors into diagnostics.
pub fn grammar_diagnostics(a: &Annotated, file: &str) -> Vec<Diagnostic> {
    a.errors
        .iter()
        .map(|(line, msg)| Diagnostic {
            file: file.to_string(),
            line: *line,
            rule: "LINT",
            msg: msg.clone(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scanner::scan;

    #[test]
    fn directive_grammar_parses_and_rejects() {
        assert_eq!(
            parse_directive("hot-path").unwrap(),
            DirectiveKind::HotPath
        );
        assert_eq!(
            parse_directive("hot-path -- slab loop").unwrap(),
            DirectiveKind::HotPath
        );
        assert_eq!(
            parse_directive("panic-free -- reader thread").unwrap(),
            DirectiveKind::PanicFree
        );
        assert_eq!(
            parse_directive("allow(A1) -- warmup only").unwrap(),
            DirectiveKind::Allow {
                rule: "A1".into()
            }
        );
        // reason is mandatory
        assert!(parse_directive("allow(A1)").is_err());
        assert!(parse_directive("allow(A1) -- ").is_err());
        // unknown rule / unknown directive / unclosed paren
        assert!(parse_directive("allow(Z9) -- x").is_err());
        assert!(parse_directive("fast-path").is_err());
        assert!(parse_directive("allow(A1 -- x").is_err());
    }

    #[test]
    fn hot_region_covers_the_next_block_only() {
        let src = "\
fn cold() { before(); }
// lint: hot-path
{
    inside();
}
fn after() { outside(); }
";
        let s = scan(src);
        let a = annotate(&s);
        assert!(a.errors.is_empty());
        let hot_ids: Vec<&str> = s
            .tokens
            .iter()
            .enumerate()
            .filter(|(i, t)| a.hot[*i] && t.kind == Tok::Ident)
            .map(|(_, t)| t.text.as_str())
            .collect();
        assert_eq!(hot_ids, vec!["inside"]);
    }

    #[test]
    fn cfg_test_mod_is_masked() {
        let src = "\
fn live() {}
#[cfg(test)]
mod tests {
    fn helper() {}
}
";
        let s = scan(src);
        let a = annotate(&s);
        let masked: Vec<&str> = s
            .tokens
            .iter()
            .enumerate()
            .filter(|(i, t)| a.in_test[*i] && t.kind == Tok::Ident)
            .map(|(_, t)| t.text.as_str())
            .collect();
        assert_eq!(masked, vec!["fn", "helper"]);
    }

    #[test]
    fn allow_covers_directive_line_and_next_code_line() {
        let src = "\
let a = 1;
// lint: allow(D2) -- legacy cast, tracked in ROADMAP
let b = seed as i32;
let c = 3;
";
        let s = scan(src);
        let a = annotate(&s);
        assert!(a.errors.is_empty());
        assert!(a.allowed("D2", 2));
        assert!(a.allowed("D2", 3));
        assert!(!a.allowed("D2", 4));
        assert!(!a.allowed("A1", 3));
        assert_eq!(a.allow_count(), 1);
    }

    #[test]
    fn unknown_directive_surfaces_as_error() {
        let src = "// lint: hot-loop\nfn f() {}\n";
        let a_scan = scan(src);
        let a = annotate(&a_scan);
        assert_eq!(a.errors.len(), 1);
        assert!(a.errors[0].1.contains("unknown lint directive"));
    }
}

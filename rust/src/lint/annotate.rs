//! The `// lint:` annotation grammar and the token-region machinery
//! built on it.
//!
//! Six directives:
//!
//! * `// lint: hot-path [-- note]` — marks the next `{ ... }` block as
//!   a steady-state region: rule **A1** forbids allocation inside it.
//! * `// lint: panic-free [-- note]` — marks the next block as a
//!   region where rule **P1** forbids `unwrap`/`expect`/`panic!` and
//!   slice indexing (a panic there poisons the shared fabric event
//!   stream instead of surfacing `Exited`/`Failed`).
//! * `// lint: proto(STATE[|STATE...]) [-- note]` — marks the next
//!   block as a protocol region: rule **S1** checks every wire tag the
//!   block mentions (and every `match` on a frame tag) against the
//!   `transport/protocol.rs` state-machine table for those states.
//! * `// lint: pooled [-- note]` — marks the next block as a region
//!   where rule **R1** requires every slab taken from a pool to be
//!   recycled on every exit path, including `?` and early returns.
//! * `// lint: deterministic [-- note]` — marks the next block as a
//!   region where rule **D3** forbids wall-clock and thread-identity
//!   reads (`Instant::now`, `SystemTime`, `thread::current().id()`).
//! * `// lint: allow(RULE) -- reason` — suppresses RULE on the
//!   directive's line and the next code line. The reason is
//!   **mandatory**: an unexplained suppression is itself a violation.
//!
//! Anything else after `// lint:` is an error — the directive channel
//! stays small enough to audit by eye.
//!
//! Besides the masks, [`Annotated`] exposes the per-file
//! function/region graph ([`Annotated::fn_spans`], the marked-region
//! span lists and the brace-matching table) that the function-level
//! rules S1 and R1 walk.

use crate::lint::report::Diagnostic;
use crate::lint::scanner::{Directive, Scan, Tok, Token};

/// Rule names the annotation grammar accepts in `allow(...)`.
pub const RULES: &[&str] = &["D1", "D2", "A1", "P1", "W1", "S1", "R1", "D3"];

/// A parsed directive.
#[derive(Clone, Debug, PartialEq)]
pub enum DirectiveKind {
    HotPath,
    PanicFree,
    Proto { states: Vec<String> },
    Pooled,
    Deterministic,
    Allow { rule: String },
}

/// Parse one directive body (the text after `// lint:`).
pub fn parse_directive(text: &str) -> Result<DirectiveKind, String> {
    let (head, note) = match text.split_once("--") {
        Some((h, n)) => (h.trim(), Some(n.trim())),
        None => (text.trim(), None),
    };
    if let Some(rest) = head.strip_prefix("allow(") {
        let Some(rule) = rest.strip_suffix(')').map(str::trim) else {
            return Err(format!("unclosed allow(...) in {text:?}"));
        };
        if !RULES.contains(&rule) {
            return Err(format!(
                "unknown rule {rule:?} in allow (rules: {})",
                RULES.join(", ")
            ));
        }
        match note {
            Some(r) if !r.is_empty() => Ok(DirectiveKind::Allow {
                rule: rule.to_string(),
            }),
            _ => Err(format!(
                "allow({rule}) needs a reason: \
                 `// lint: allow({rule}) -- why this is sound`"
            )),
        }
    } else if let Some(rest) = head.strip_prefix("proto(") {
        let Some(list) = rest.strip_suffix(')') else {
            return Err(format!("unclosed proto(...) in {text:?}"));
        };
        let states: Vec<String> = list
            .split('|')
            .map(|s| s.trim().to_string())
            .collect();
        let ok = !states.is_empty()
            && states.iter().all(|s| {
                !s.is_empty()
                    && s.chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_')
            });
        if !ok {
            return Err(format!(
                "proto(...) wants `|`-separated state names, got \
                 {list:?}"
            ));
        }
        Ok(DirectiveKind::Proto { states })
    } else {
        match head {
            "hot-path" => Ok(DirectiveKind::HotPath),
            "panic-free" => Ok(DirectiveKind::PanicFree),
            "pooled" => Ok(DirectiveKind::Pooled),
            "deterministic" => Ok(DirectiveKind::Deterministic),
            other => Err(format!(
                "unknown lint directive {other:?} \
                 (hot-path, panic-free, proto(STATE|...), pooled, \
                 deterministic, allow(RULE) -- reason)"
            )),
        }
    }
}

/// A `proto(...)`-marked token span: the states the region may sit in
/// and the `{`/`}` token indices that bound it.
#[derive(Clone, Debug)]
pub struct ProtoRegion {
    pub states: Vec<String>,
    pub open: usize,
    pub close: usize,
    pub line: u32,
}

/// A `pooled`-marked token span.
#[derive(Clone, Debug)]
pub struct PooledRegion {
    pub open: usize,
    pub close: usize,
    pub line: u32,
}

/// One function body in the per-file function graph: `fn name`'s `{`
/// and `}` token indices. Trait-method declarations without a body are
/// not listed.
#[derive(Clone, Debug)]
pub struct FnSpan {
    pub name: String,
    pub open: usize,
    pub close: usize,
}

/// Everything rules need besides the raw tokens: brace matching, the
/// `#[cfg(test)] mod` mask, marked regions and the allow table.
pub struct Annotated<'a> {
    pub tokens: &'a [Token],
    /// Brace pairing: `matching[i] = Some(j)` for `{`/`}` tokens.
    pub matching: Vec<Option<usize>>,
    /// `in_test[i]` — token i sits inside a `#[cfg(test)] mod` block.
    pub in_test: Vec<bool>,
    /// `hot[i]` — token i sits inside a `// lint: hot-path` block.
    pub hot: Vec<bool>,
    /// `panic_free[i]` — token i sits inside a `// lint: panic-free`
    /// block.
    pub panic_free: Vec<bool>,
    /// `deterministic[i]` — token i sits inside a
    /// `// lint: deterministic` block.
    pub deterministic: Vec<bool>,
    /// `proto(...)` regions, in directive order.
    pub proto_regions: Vec<ProtoRegion>,
    /// `pooled` regions, in directive order.
    pub pooled_regions: Vec<PooledRegion>,
    /// (rule, line) pairs with an active `allow`.
    allows: Vec<(String, u32)>,
    /// Number of `allow` directives (each expands to two `allows`
    /// entries: its own line and the next code line).
    allow_directives: usize,
    /// Grammar errors to surface as diagnostics.
    pub errors: Vec<(u32, String)>,
}

impl<'a> Annotated<'a> {
    /// Is `rule` suppressed on `line`?
    pub fn allowed(&self, rule: &str, line: u32) -> bool {
        self.allows
            .iter()
            .any(|(r, l)| r == rule && line == *l)
    }

    /// Number of `allow` directives in the file (any rule).
    pub fn allow_count(&self) -> usize {
        self.allow_directives
    }

    /// The per-file function graph: every `fn name ... { ... }` body,
    /// in source order (nested fns included — each is its own node).
    pub fn fn_spans(&self) -> Vec<FnSpan> {
        let mut out = Vec::new();
        let toks = self.tokens;
        for i in 0..toks.len() {
            if !toks[i].is_ident("fn") {
                continue;
            }
            let Some(name_tok) = toks.get(i + 1) else {
                continue;
            };
            if name_tok.kind != Tok::Ident {
                continue;
            }
            // the body `{` is the first brace before any top-level `;`
            // (a `;` first means a bodiless trait/extern declaration;
            // `;` inside `(..)`/`[..]` — e.g. `[u8; 4]` — doesn't count)
            let mut j = i + 2;
            let mut depth = 0i32;
            let open = loop {
                match toks.get(j) {
                    Some(t) if t.is_punct('(') || t.is_punct('[') => {
                        depth += 1;
                        j += 1;
                    }
                    Some(t) if t.is_punct(')') || t.is_punct(']') => {
                        depth -= 1;
                        j += 1;
                    }
                    Some(t) if t.is_punct('{') && depth == 0 => {
                        break Some(j)
                    }
                    Some(t) if t.is_punct(';') && depth == 0 => {
                        break None
                    }
                    Some(_) => j += 1,
                    None => break None,
                }
            };
            if let Some(open) = open {
                if let Some(Some(close)) = self.matching.get(open) {
                    out.push(FnSpan {
                        name: name_tok.text.clone(),
                        open,
                        close: *close,
                    });
                }
            }
        }
        out
    }

    /// Name of the function whose body contains token `i`, preferring
    /// the innermost enclosing `fn`.
    pub fn enclosing_fn_name(&self, i: usize) -> Option<String> {
        self.fn_spans()
            .into_iter()
            .filter(|f| f.open <= i && i <= f.close)
            .min_by_key(|f| f.close - f.open)
            .map(|f| f.name)
    }
}

/// Build the [`Annotated`] view of a scan.
pub fn annotate<'a>(scan: &'a Scan) -> Annotated<'a> {
    let tokens = &scan.tokens;
    let matching = match_braces(tokens);
    let mut a = Annotated {
        tokens,
        in_test: test_mask(tokens, &matching),
        hot: vec![false; tokens.len()],
        panic_free: vec![false; tokens.len()],
        deterministic: vec![false; tokens.len()],
        proto_regions: Vec::new(),
        pooled_regions: Vec::new(),
        matching,
        allows: Vec::new(),
        allow_directives: 0,
        errors: Vec::new(),
    };
    for d in &scan.directives {
        match parse_directive(&d.text) {
            Ok(DirectiveKind::HotPath) => {
                mark_next_block(tokens, &a.matching, d, &mut a.hot)
                    .map(|_| ())
                    .unwrap_or_else(|e| a.errors.push((d.line, e)));
            }
            Ok(DirectiveKind::PanicFree) => {
                mark_next_block(tokens, &a.matching, d, &mut a.panic_free)
                    .map(|_| ())
                    .unwrap_or_else(|e| a.errors.push((d.line, e)));
            }
            Ok(DirectiveKind::Deterministic) => {
                mark_next_block(tokens, &a.matching, d, &mut a.deterministic)
                    .map(|_| ())
                    .unwrap_or_else(|e| a.errors.push((d.line, e)));
            }
            Ok(DirectiveKind::Proto { states }) => {
                let mut scratch = vec![false; tokens.len()];
                match mark_next_block(tokens, &a.matching, d, &mut scratch)
                {
                    Ok((open, close)) => a.proto_regions.push(ProtoRegion {
                        states,
                        open,
                        close,
                        line: d.line,
                    }),
                    Err(e) => a.errors.push((d.line, e)),
                }
            }
            Ok(DirectiveKind::Pooled) => {
                let mut scratch = vec![false; tokens.len()];
                match mark_next_block(tokens, &a.matching, d, &mut scratch)
                {
                    Ok((open, close)) => a.pooled_regions.push(PooledRegion {
                        open,
                        close,
                        line: d.line,
                    }),
                    Err(e) => a.errors.push((d.line, e)),
                }
            }
            Ok(DirectiveKind::Allow { rule }) => {
                // the directive's own line plus the next code line, so
                // the annotation can sit above the statement it excuses
                a.allow_directives += 1;
                a.allows.push((rule.clone(), d.line));
                if let Some(next) = tokens
                    .iter()
                    .map(|t| t.line)
                    .filter(|&l| l > d.line)
                    .min()
                {
                    a.allows.push((rule, next));
                }
            }
            Err(e) => a.errors.push((d.line, e)),
        }
    }
    a
}

/// `matching[i] = Some(j)` for brace tokens, pairing `{`...`}`.
fn match_braces(tokens: &[Token]) -> Vec<Option<usize>> {
    let mut matching = vec![None; tokens.len()];
    let mut stack = Vec::new();
    for (i, t) in tokens.iter().enumerate() {
        if t.is_punct('{') {
            stack.push(i);
        } else if t.is_punct('}') {
            if let Some(open) = stack.pop() {
                matching[open] = Some(i);
                matching[i] = Some(open);
            }
        }
    }
    matching
}

/// Mark the tokens of `#[cfg(test)] mod <name> { ... }` blocks (and
/// any other `#[cfg(test)]`-attributed braced item). Test code is
/// exempt from the steady-state rules — it is allowed to allocate,
/// unwrap and index.
fn test_mask(tokens: &[Token], matching: &[Option<usize>]) -> Vec<bool> {
    let mut mask = vec![false; tokens.len()];
    let mut i = 0usize;
    while i < tokens.len() {
        if is_cfg_test_at(tokens, i) {
            // find the first `{` after the attribute and mask its block
            let mut j = i;
            while j < tokens.len() && !tokens[j].is_punct('{') {
                j += 1;
            }
            if let Some(Some(close)) = matching.get(j) {
                for slot in &mut mask[j..=*close] {
                    *slot = true;
                }
                i = *close + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// Does `#` `[` `cfg` `(` `test` `)` `]` start at token `i`?
fn is_cfg_test_at(tokens: &[Token], i: usize) -> bool {
    let pat: &[&dyn Fn(&Token) -> bool] = &[
        &|t: &Token| t.is_punct('#'),
        &|t: &Token| t.is_punct('['),
        &|t: &Token| t.is_ident("cfg"),
        &|t: &Token| t.is_punct('('),
        &|t: &Token| t.is_ident("test"),
        &|t: &Token| t.is_punct(')'),
        &|t: &Token| t.is_punct(']'),
    ];
    tokens.len() >= i + pat.len()
        && pat
            .iter()
            .zip(&tokens[i..])
            .all(|(p, t)| p(t))
}

/// Mark the block opened by the first `{` at or after the directive's
/// line; returns the `(open, close)` token span.
fn mark_next_block(
    tokens: &[Token],
    matching: &[Option<usize>],
    d: &Directive,
    mask: &mut [bool],
) -> Result<(usize, usize), String> {
    let open = tokens
        .iter()
        .position(|t| t.is_punct('{') && t.line >= d.line)
        .ok_or_else(|| {
            format!("no `{{` block follows the directive {:?}", d.text)
        })?;
    let close = matching[open]
        .ok_or_else(|| format!("unbalanced block after {:?}", d.text))?;
    for slot in &mut mask[open..=close] {
        *slot = true;
    }
    Ok((open, close))
}

/// Turn this file's grammar errors into diagnostics.
pub fn grammar_diagnostics(a: &Annotated, file: &str) -> Vec<Diagnostic> {
    a.errors
        .iter()
        .map(|(line, msg)| Diagnostic {
            file: file.to_string(),
            line: *line,
            rule: "LINT",
            msg: msg.clone(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scanner::scan;

    #[test]
    fn directive_grammar_parses_and_rejects() {
        assert_eq!(
            parse_directive("hot-path").unwrap(),
            DirectiveKind::HotPath
        );
        assert_eq!(
            parse_directive("hot-path -- slab loop").unwrap(),
            DirectiveKind::HotPath
        );
        assert_eq!(
            parse_directive("panic-free -- reader thread").unwrap(),
            DirectiveKind::PanicFree
        );
        assert_eq!(
            parse_directive("allow(A1) -- warmup only").unwrap(),
            DirectiveKind::Allow {
                rule: "A1".into()
            }
        );
        // reason is mandatory
        assert!(parse_directive("allow(A1)").is_err());
        assert!(parse_directive("allow(A1) -- ").is_err());
        // unknown rule / unknown directive / unclosed paren
        assert!(parse_directive("allow(Z9) -- x").is_err());
        assert!(parse_directive("fast-path").is_err());
        assert!(parse_directive("allow(A1 -- x").is_err());
    }

    #[test]
    fn hot_region_covers_the_next_block_only() {
        let src = "\
fn cold() { before(); }
// lint: hot-path
{
    inside();
}
fn after() { outside(); }
";
        let s = scan(src);
        let a = annotate(&s);
        assert!(a.errors.is_empty());
        let hot_ids: Vec<&str> = s
            .tokens
            .iter()
            .enumerate()
            .filter(|(i, t)| a.hot[*i] && t.kind == Tok::Ident)
            .map(|(_, t)| t.text.as_str())
            .collect();
        assert_eq!(hot_ids, vec!["inside"]);
    }

    #[test]
    fn cfg_test_mod_is_masked() {
        let src = "\
fn live() {}
#[cfg(test)]
mod tests {
    fn helper() {}
}
";
        let s = scan(src);
        let a = annotate(&s);
        let masked: Vec<&str> = s
            .tokens
            .iter()
            .enumerate()
            .filter(|(i, t)| a.in_test[*i] && t.kind == Tok::Ident)
            .map(|(_, t)| t.text.as_str())
            .collect();
        assert_eq!(masked, vec!["fn", "helper"]);
    }

    #[test]
    fn allow_covers_directive_line_and_next_code_line() {
        let src = "\
let a = 1;
// lint: allow(D2) -- legacy cast, tracked in ROADMAP
let b = seed as i32;
let c = 3;
";
        let s = scan(src);
        let a = annotate(&s);
        assert!(a.errors.is_empty());
        assert!(a.allowed("D2", 2));
        assert!(a.allowed("D2", 3));
        assert!(!a.allowed("D2", 4));
        assert!(!a.allowed("A1", 3));
        assert_eq!(a.allow_count(), 1);
    }

    #[test]
    fn unknown_directive_surfaces_as_error() {
        let src = "// lint: hot-loop\nfn f() {}\n";
        let a_scan = scan(src);
        let a = annotate(&a_scan);
        assert_eq!(a.errors.len(), 1);
        assert!(a.errors[0].1.contains("unknown lint directive"));
    }

    #[test]
    fn proto_and_pooled_directives_carry_region_spans() {
        let src = "\
fn handshake() {
    // lint: proto(Hello|RoundLoop) -- connect path
    {
        observe();
    }
    // lint: pooled
    {
        take();
    }
}
";
        let s = scan(src);
        let a = annotate(&s);
        assert!(a.errors.is_empty(), "{:?}", a.errors);
        assert_eq!(a.proto_regions.len(), 1);
        let pr = &a.proto_regions[0];
        assert_eq!(pr.states, vec!["Hello", "RoundLoop"]);
        let in_proto: Vec<&str> = s.tokens[pr.open..=pr.close]
            .iter()
            .filter(|t| t.kind == Tok::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(in_proto, vec!["observe"]);
        assert_eq!(a.pooled_regions.len(), 1);
        let po = &a.pooled_regions[0];
        let in_pool: Vec<&str> = s.tokens[po.open..=po.close]
            .iter()
            .filter(|t| t.kind == Tok::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(in_pool, vec!["take"]);
    }

    #[test]
    fn deterministic_region_masks_like_the_others() {
        let src = "\
fn cold() { now(); }
// lint: deterministic -- reduce kernel
{
    reduce();
}
";
        let s = scan(src);
        let a = annotate(&s);
        assert!(a.errors.is_empty());
        let marked: Vec<&str> = s
            .tokens
            .iter()
            .enumerate()
            .filter(|(i, t)| a.deterministic[*i] && t.kind == Tok::Ident)
            .map(|(_, t)| t.text.as_str())
            .collect();
        assert_eq!(marked, vec!["reduce"]);
    }

    #[test]
    fn proto_grammar_rejects_bad_state_lists() {
        assert!(parse_directive("proto()").is_err());
        assert!(parse_directive("proto(A|)").is_err());
        assert!(parse_directive("proto(A B)").is_err());
        assert!(parse_directive("proto(Hello").is_err());
        let ok = parse_directive("proto(InFlight|Draining) -- reader")
            .unwrap();
        assert_eq!(
            ok,
            DirectiveKind::Proto {
                states: vec!["InFlight".into(), "Draining".into()]
            }
        );
    }

    #[test]
    fn fn_spans_build_the_function_graph() {
        let src = "\
trait T { fn decl(&self) -> [u8; 4]; }
fn outer(x: [u8; 2]) {
    fn inner() { body(); }
    tail();
}
";
        let s = scan(src);
        let a = annotate(&s);
        let spans = a.fn_spans();
        let names: Vec<&str> =
            spans.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["outer", "inner"]);
        let body_at = s
            .tokens
            .iter()
            .position(|t| t.is_ident("body"))
            .unwrap();
        assert_eq!(a.enclosing_fn_name(body_at).as_deref(), Some("inner"));
        let tail_at = s
            .tokens
            .iter()
            .position(|t| t.is_ident("tail"))
            .unwrap();
        assert_eq!(a.enclosing_fn_name(tail_at).as_deref(), Some("outer"));
    }
}

//! Comment/string-stripping token scanner.
//!
//! `pallas-lint` deliberately does not parse Rust — no `syn`, no AST,
//! matching the repo's nanoserde-style minimalism. The rules only need
//! a faithful *token* stream with line numbers: identifiers, numbers
//! and single-character punctuation, with comments, strings, chars and
//! lifetimes lexed (so their contents can never fake a match) and
//! collapsed into opaque tokens. The one thing comments contribute is
//! the `// lint:` directive channel ([`Directive`]), which the
//! annotation grammar consumes separately.

/// What a token is. String/char literals are kept as opaque markers so
/// rules can reason about positions without ever matching their bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`foo`, `fn`, `HashMap`).
    Ident,
    /// Numeric literal, suffix included (`1.0f32`, `0x_ff`).
    Num,
    /// One punctuation character (`{`, `.`, `!`, ...).
    Punct(char),
    /// String literal (normal, raw or byte), contents stripped.
    Str,
    /// Char or byte-char literal, contents stripped.
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: Tok,
    /// Source text for `Ident`/`Num`; empty for everything else.
    pub text: String,
    pub line: u32,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Tok::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Tok::Punct(c)
    }
}

/// A `// lint: ...` comment: everything after the `lint:` marker,
/// trimmed, plus the line it sits on. Grammar is parsed by
/// [`crate::lint::annotate`].
#[derive(Clone, Debug)]
pub struct Directive {
    pub text: String,
    pub line: u32,
}

/// A scanned file: the stripped token stream and the lint directives.
#[derive(Debug, Default)]
pub struct Scan {
    pub tokens: Vec<Token>,
    pub directives: Vec<Directive>,
}

/// Marker a line comment must open with (after `//` and whitespace) to
/// enter the directive channel.
const DIRECTIVE_MARKER: &str = "lint:";

/// Lex `src` into a [`Scan`]. Never fails: unterminated literals lex
/// to the end of the file (the compiler owns syntax errors; the linter
/// only needs to stay sane on them).
pub fn scan(src: &str) -> Scan {
    let b = src.as_bytes();
    let mut out = Scan::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_ascii_whitespace() => i += 1,
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i + 2;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                let body = &src[start..i];
                // `///` outer and `//!` inner doc comments are
                // documentation, never directives — rustdoc prose
                // quoting the `lint:` grammar must not open a region
                let is_doc = (body.starts_with('/')
                    && !body.starts_with("//"))
                    || body.starts_with('!');
                if !is_doc {
                    let comment = body.trim_start_matches('/').trim();
                    if let Some(rest) =
                        comment.strip_prefix(DIRECTIVE_MARKER)
                    {
                        out.directives.push(Directive {
                            text: rest.trim().to_string(),
                            line,
                        });
                    }
                }
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                i += 2;
                let mut depth = 1u32;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => {
                let tline = line;
                i = skip_string(b, i, &mut line);
                out.tokens.push(tok(Tok::Str, tline));
            }
            b'\'' => {
                // lifetime or char literal
                let next = b.get(i + 1).copied();
                let is_lifetime = matches!(
                    next,
                    Some(n) if n == b'_' || n.is_ascii_alphabetic()
                ) && {
                    // 'a' is a char, 'a + ident chars not followed by a
                    // closing quote is a lifetime
                    let mut j = i + 1;
                    while j < b.len()
                        && (b[j] == b'_' || b[j].is_ascii_alphanumeric())
                    {
                        j += 1;
                    }
                    b.get(j) != Some(&b'\'')
                };
                if is_lifetime {
                    let mut j = i + 1;
                    while j < b.len()
                        && (b[j] == b'_' || b[j].is_ascii_alphanumeric())
                    {
                        j += 1;
                    }
                    out.tokens.push(tok(Tok::Lifetime, line));
                    i = j;
                } else {
                    let tline = line;
                    i = skip_char(b, i, &mut line);
                    out.tokens.push(tok(Tok::Char, tline));
                }
            }
            c if c == b'_' || c.is_ascii_alphabetic() => {
                // byte-char literal `b'x'`: one Char token, not an
                // ident `b` followed by a stray quote
                if c == b'b' && b.get(i + 1) == Some(&b'\'') {
                    let tline = line;
                    i = skip_char(b, i + 1, &mut line);
                    out.tokens.push(tok(Tok::Char, tline));
                    continue;
                }
                // raw identifier `r#match`: one ident carrying the
                // bare name (that is its meaning to the compiler)
                if c == b'r'
                    && b.get(i + 1) == Some(&b'#')
                    && b.get(i + 2).is_some_and(|&n| {
                        n == b'_' || n.is_ascii_alphabetic()
                    })
                {
                    let start = i + 2;
                    let mut j = start;
                    while j < b.len()
                        && (b[j] == b'_' || b[j].is_ascii_alphanumeric())
                    {
                        j += 1;
                    }
                    out.tokens.push(Token {
                        kind: Tok::Ident,
                        text: src[start..j].to_string(),
                        line,
                    });
                    i = j;
                    continue;
                }
                // raw/byte string prefixes lex as string literals, not
                // as an ident followed by a stray quote
                if let Some(end) = raw_or_byte_string(b, i) {
                    let tline = line;
                    line += src[i..end].matches('\n').count() as u32;
                    out.tokens.push(tok(Tok::Str, tline));
                    i = end;
                    continue;
                }
                let start = i;
                while i < b.len()
                    && (b[i] == b'_' || b[i].is_ascii_alphanumeric())
                {
                    i += 1;
                }
                out.tokens.push(Token {
                    kind: Tok::Ident,
                    text: src[start..i].to_string(),
                    line,
                });
            }
            c if c.is_ascii_digit() => {
                let start = i;
                loop {
                    while i < b.len()
                        && (b[i] == b'_' || b[i].is_ascii_alphanumeric())
                    {
                        i += 1;
                    }
                    // fractional part: `1.5` but not the range `1..5`
                    if i < b.len()
                        && b[i] == b'.'
                        && b.get(i + 1)
                            .is_some_and(|d| d.is_ascii_digit())
                    {
                        i += 1;
                        continue;
                    }
                    // exponent sign: `1e-3`
                    if i > start
                        && (b[i - 1] == b'e' || b[i - 1] == b'E')
                        && i < b.len()
                        && (b[i] == b'+' || b[i] == b'-')
                        && b.get(i + 1)
                            .is_some_and(|d| d.is_ascii_digit())
                    {
                        i += 1;
                        continue;
                    }
                    break;
                }
                out.tokens.push(Token {
                    kind: Tok::Num,
                    text: src[start..i].to_string(),
                    line,
                });
            }
            c => {
                out.tokens.push(Token {
                    kind: Tok::Punct(c as char),
                    text: String::new(),
                    line,
                });
                i += 1;
            }
        }
    }
    out
}

fn tok(kind: Tok, line: u32) -> Token {
    Token {
        kind,
        text: String::new(),
        line,
    }
}

/// Skip a normal `"..."` literal starting at the opening quote; returns
/// the index past the closing quote and counts newlines into `line`.
fn skip_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => {
                // a `\`+newline continuation is still a source line
                if b.get(i + 1) == Some(&b'\n') {
                    *line += 1;
                }
                i += 2;
            }
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Skip a `'x'` / `'\n'` char literal starting at the quote.
fn skip_char(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => {
                if b.get(i + 1) == Some(&b'\n') {
                    *line += 1;
                }
                i += 2;
            }
            b'\'' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// If `i` starts a raw or byte string (`r"`, `r#"`, `b"`, `br#"`, ...),
/// return the index past its end.
fn raw_or_byte_string(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    let mut raw = false;
    // prefix: r, b, br, rb
    for _ in 0..2 {
        match b.get(j) {
            Some(b'r') => {
                raw = true;
                j += 1;
            }
            Some(b'b') if !raw => j += 1,
            _ => break,
        }
    }
    if j == i {
        return None;
    }
    let mut hashes = 0usize;
    if raw {
        while b.get(j) == Some(&b'#') {
            hashes += 1;
            j += 1;
        }
    }
    if b.get(j) != Some(&b'"') {
        return None;
    }
    j += 1;
    if raw {
        // ends at `"` followed by `hashes` hash marks, no escapes
        while j < b.len() {
            if b[j] == b'"'
                && b[j + 1..].len() >= hashes
                && b[j + 1..j + 1 + hashes].iter().all(|&h| h == b'#')
            {
                return Some(j + 1 + hashes);
            }
            j += 1;
        }
        Some(j)
    } else {
        // byte string with normal escape rules
        while j < b.len() {
            match b[j] {
                b'\\' => j += 2,
                b'"' => return Some(j + 1),
                _ => j += 1,
            }
        }
        Some(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        scan(src)
            .tokens
            .iter()
            .filter(|t| t.kind == Tok::Ident)
            .map(|t| t.text.clone())
            .collect()
    }

    #[test]
    fn strings_and_comments_never_leak_tokens() {
        let src = r##"
            let a = "unwrap() inside a string"; // unwrap in a comment
            /* block with panic!() inside */
            let b = 'x';
            let s = r#"raw with HashMap"#;
        "##;
        let ids = idents(src);
        assert!(!ids.iter().any(|t| t == "unwrap" || t == "HashMap"
            || t == "panic"));
        assert_eq!(
            ids,
            vec!["let", "a", "let", "b", "let", "s"]
        );
    }

    #[test]
    fn directives_are_captured_with_lines() {
        let src = "fn f() {}\n// lint: hot-path -- note\nfn g() {}\n";
        let s = scan(src);
        assert_eq!(s.directives.len(), 1);
        assert_eq!(s.directives[0].line, 2);
        assert_eq!(s.directives[0].text, "hot-path -- note");
    }

    #[test]
    fn lines_are_tracked_through_literals() {
        let src = "let a = \"two\nlines\";\nlet tail = 1;";
        let s = scan(src);
        let tail = s
            .tokens
            .iter()
            .find(|t| t.is_ident("tail"))
            .unwrap();
        assert_eq!(tail.line, 3);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { 'y' }";
        let s = scan(src);
        let lifetimes =
            s.tokens.iter().filter(|t| t.kind == Tok::Lifetime).count();
        let chars =
            s.tokens.iter().filter(|t| t.kind == Tok::Char).count();
        assert_eq!((lifetimes, chars), (2, 1));
    }

    #[test]
    fn raw_strings_with_hashes_quotes_and_newlines_stay_opaque() {
        // embedded `"#` (fewer hashes than the guard), trigger idents,
        // comment- and directive-lookalikes, and a newline — the whole
        // literal must collapse to ONE Str token with lines tracked
        let src = "let a = r##\"quote \" hash # \"# unwrap() HashMap\n\
                   /* no comment */ // lint: hot-path\"##;\n\
                   let tail = 0;";
        let s = scan(src);
        assert!(!s
            .tokens
            .iter()
            .any(|t| t.is_ident("unwrap") || t.is_ident("HashMap")));
        assert!(s.directives.is_empty());
        assert_eq!(
            s.tokens.iter().filter(|t| t.kind == Tok::Str).count(),
            1
        );
        let tail =
            s.tokens.iter().find(|t| t.is_ident("tail")).unwrap();
        assert_eq!(tail.line, 3);
    }

    #[test]
    fn byte_and_byte_raw_strings_are_single_tokens() {
        let src = "let a = b\"escaped \\\" unwrap()\";\n\
                   let c = br#\"hash # panic!()\"#;";
        let s = scan(src);
        assert!(!s
            .tokens
            .iter()
            .any(|t| t.is_ident("unwrap") || t.is_ident("panic")));
        assert_eq!(
            s.tokens.iter().filter(|t| t.kind == Tok::Str).count(),
            2
        );
    }

    #[test]
    fn nested_block_comments_strip_fully_and_track_lines() {
        let src = "/* outer /* inner panic!() */ still stripped\n\
                   lint: hot-path */\n\
                   let tail = 1;";
        let s = scan(src);
        assert!(s.directives.is_empty());
        assert!(!s.tokens.iter().any(|t| t.is_ident("panic")
            || t.is_ident("still")
            || t.is_ident("lint")));
        let tail =
            s.tokens.iter().find(|t| t.is_ident("tail")).unwrap();
        assert_eq!(tail.line, 3);
    }

    #[test]
    fn doc_comments_never_enter_the_directive_channel() {
        // rustdoc prose about the grammar must not open regions; a
        // plain `// lint:` on the next line still does
        let src = "/// lint: hot-path\n\
                   //! lint: panic-free\n\
                   // lint: hot-path\n\
                   fn f() {}\n";
        let s = scan(src);
        assert_eq!(s.directives.len(), 1);
        assert_eq!(s.directives[0].line, 3);
        assert_eq!(s.directives[0].text, "hot-path");
    }

    #[test]
    fn byte_char_literals_do_not_fabricate_idents() {
        let src = "let x = b'x'; let y = b'\\n'; let z = b'\\'';";
        let s = scan(src);
        assert!(!s.tokens.iter().any(|t| t.is_ident("b")));
        assert_eq!(
            s.tokens.iter().filter(|t| t.kind == Tok::Char).count(),
            3
        );
        assert!(s.tokens.iter().any(|t| t.is_ident("z")));
    }

    #[test]
    fn raw_identifiers_lex_as_their_bare_name() {
        let src = "let r#type = 1; r#loop(); let s = r#\"raw\"#;";
        let s = scan(src);
        assert!(s.tokens.iter().any(|t| t.is_ident("type")));
        assert!(s.tokens.iter().any(|t| t.is_ident("loop")));
        assert!(!s.tokens.iter().any(|t| t.is_ident("r")));
        assert_eq!(
            s.tokens.iter().filter(|t| t.kind == Tok::Str).count(),
            1
        );
    }

    #[test]
    fn escaped_newlines_in_literals_keep_line_numbers() {
        // `\`+newline string continuation is still a source line
        let src = "let a = \"one\\\ntwo\";\nlet tail = 1;";
        let s = scan(src);
        let tail =
            s.tokens.iter().find(|t| t.is_ident("tail")).unwrap();
        assert_eq!(tail.line, 3);
    }

    #[test]
    fn numbers_swallow_suffixes_and_ranges_survive() {
        let src = "let x = 1.5f32; for i in 0..n_max { }";
        let s = scan(src);
        let nums: Vec<&str> = s
            .tokens
            .iter()
            .filter(|t| t.kind == Tok::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["1.5f32", "0"]);
        assert!(s.tokens.iter().any(|t| t.is_ident("n_max")));
    }
}

//! `pallas-lint`: the repo's in-tree static invariant checker.
//!
//! Parle's reproducibility claims rest on invariants the type system
//! cannot express: bit-exact total-order reduction, seed-derivation
//! hygiene, zero steady-state allocation in the fabric loops,
//! panic-free worker/reader threads, and cap-checked wire allocations.
//! This module turns those house rules into machine-checked gates —
//! see [`rules`] for the rule catalogue and [`annotate`] for the
//! `// lint:` annotation grammar.
//!
//! Deliberately zero-dependency: a comment/string-stripping token
//! scanner ([`scanner`]), not an AST. The rules are token patterns; a
//! full parse buys nothing but a `syn` dependency.
//!
//! Run via `cargo run --bin pallas_lint` (exits nonzero on any
//! violation) or programmatically through [`lint_tree`].

pub mod annotate;
pub mod proto;
pub mod report;
pub mod rules;
pub mod scanner;

use std::fs;
use std::path::{Path, PathBuf};

use crate::Result;
use anyhow::Context;
use report::Diagnostic;

/// Result of linting a directory tree.
#[derive(Debug, Default)]
pub struct TreeReport {
    /// All diagnostics, across files.
    pub diagnostics: Vec<Diagnostic>,
    /// Files scanned (lint-relative display paths, sorted).
    pub files: Vec<String>,
    /// Per-file `// lint: allow` suppression counts (same order as
    /// `files`), for the no-suppression gate on the fabric.
    pub suppressions: Vec<usize>,
}

impl TreeReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Total suppressions in files whose display path contains `frag`.
    pub fn suppressions_in(&self, frag: &str) -> usize {
        self.files
            .iter()
            .zip(&self.suppressions)
            .filter(|(f, _)| f.contains(frag))
            .map(|(_, n)| n)
            .sum()
    }
}

/// Lint every `.rs` file under the given roots (recursively; a root
/// may also be a single file), in deterministic sorted order.
/// `display_base` is stripped from paths in diagnostics so output is
/// stable regardless of where the binary runs.
pub fn lint_tree(roots: &[&Path], display_base: &Path) -> Result<TreeReport> {
    let mut files = Vec::new();
    for root in roots {
        if root.is_file() {
            files.push(root.to_path_buf());
        } else {
            collect_rs_files(root, &mut files)
                .with_context(|| format!("walking {}", root.display()))?;
        }
    }
    files.sort();
    let mut report = TreeReport::default();
    // the protocol table is context for every file's S1 pass: parse it
    // once, out of the same file set being linted, so the spec the
    // checker enforces is the one the tree compiles
    let mut table = None;
    for path in &files {
        let display = path
            .strip_prefix(display_base)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        if !display.ends_with("transport/protocol.rs") {
            continue;
        }
        let src = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        match proto::parse_table(&src) {
            Ok(t) => table = Some(t),
            Err(e) => report.diagnostics.push(Diagnostic {
                file: display,
                line: 1,
                rule: "S1",
                msg: e,
            }),
        }
    }
    for path in files {
        let src = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let display = path
            .strip_prefix(display_base)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        report.diagnostics.extend(rules::lint_source_with(
            &display,
            &src,
            table.as_ref(),
        ));
        report.suppressions.push(rules::suppression_count(&src));
        report.files.push(display);
    }
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_tree_walks_this_module_clean() {
        // the lint module itself is not on the reduce path and has no
        // marked regions, so it must lint clean
        let base = Path::new(env!("CARGO_MANIFEST_DIR"));
        let lint_dir = base.join("src/lint");
        let report = lint_tree(&[&lint_dir], base).unwrap();
        assert!(
            report.is_clean(),
            "lint module has violations:\n{}",
            report::render(&report.diagnostics)
        );
        assert!(report.files.iter().any(|f| f.ends_with("scanner.rs")));
    }
}

//! The wire-protocol table, parsed from `transport/protocol.rs`
//! SOURCE text.
//!
//! The S1 rule checks `// lint: proto(STATE)` regions against the
//! protocol state machine. To make drift impossible, the checker does
//! not embed its own copy of the machine: it re-reads the
//! `TRANSITIONS` const out of `protocol.rs` with the same token
//! scanner the linter already uses, so the table the compiler builds
//! into the runtime monitors and the table the linter enforces are one
//! artifact. A unit test in `protocol.rs`
//! (`table_matches_lint_parser`) pins the two byte-for-byte.
//!
//! The parser is deliberately rigid: rows must be literal
//! `(State::X, Dir::Y, wire::TAG_Z, State::W)` tuples. A row the
//! parser cannot read is a lint error, not a silent skip — a protocol
//! table that cannot be machine-checked is itself a violation.

use std::collections::BTreeSet;

use crate::lint::scanner::{scan, Tok, Token};

/// One `(from, dir, tag, to)` table row, names exactly as written.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProtoRow {
    pub from: String,
    pub dir: String,
    pub tag: String,
    pub to: String,
}

/// The parsed protocol table.
#[derive(Clone, Debug, Default)]
pub struct ProtoTable {
    pub rows: Vec<ProtoRow>,
}

impl ProtoTable {
    /// Whether `name` appears as a state anywhere in the table.
    pub fn has_state(&self, name: &str) -> bool {
        self.rows
            .iter()
            .any(|r| r.from == name || r.to == name)
    }

    /// Tags legal in any of `states`, either direction: the complete
    /// vocabulary a protocol region for those states may mention.
    pub fn tags_in(&self, states: &[String]) -> BTreeSet<String> {
        self.rows
            .iter()
            .filter(|r| states.iter().any(|s| *s == r.from))
            .map(|r| r.tag.clone())
            .collect()
    }

    /// Tags an endpoint may RECEIVE across `states`: `dir` is the
    /// table's direction name ("ToWorker" for a worker-side dispatch
    /// site, "ToMaster" for a master-side one).
    pub fn tags_in_dir(&self, states: &[String], dir: &str)
                       -> BTreeSet<String> {
        self.rows
            .iter()
            .filter(|r| {
                r.dir == dir && states.iter().any(|s| *s == r.from)
            })
            .map(|r| r.tag.clone())
            .collect()
    }
}

/// Token cursor with rigid expectations and line-stamped errors.
struct Cur<'a> {
    toks: &'a [Token],
    i: usize,
}

impl<'a> Cur<'a> {
    fn peek(&self) -> Option<&'a Token> {
        self.toks.get(self.i)
    }

    fn line(&self) -> u32 {
        self.peek().map(|t| t.line).unwrap_or(0)
    }

    fn bump(&mut self) -> Option<&'a Token> {
        let t = self.toks.get(self.i);
        self.i += 1;
        t
    }

    fn expect_punct(&mut self, c: char) -> Result<(), String> {
        match self.bump() {
            Some(t) if t.is_punct(c) => Ok(()),
            Some(t) => Err(format!(
                "protocol table: expected `{c}` at line {}, found {:?}",
                t.line, t.kind
            )),
            None => Err(format!(
                "protocol table: expected `{c}`, found end of file"
            )),
        }
    }

    fn expect_ident(&mut self, s: &str) -> Result<(), String> {
        match self.bump() {
            Some(t) if t.is_ident(s) => Ok(()),
            Some(t) => Err(format!(
                "protocol table: expected `{s}` at line {}, found \
                 {:?} {:?}",
                t.line, t.kind, t.text
            )),
            None => Err(format!(
                "protocol table: expected `{s}`, found end of file"
            )),
        }
    }

    fn ident(&mut self) -> Result<&'a str, String> {
        match self.bump() {
            Some(t) if t.kind == Tok::Ident => Ok(&t.text),
            Some(t) => Err(format!(
                "protocol table: expected an identifier at line {}, \
                 found {:?}",
                t.line, t.kind
            )),
            None => Err(
                "protocol table: expected an identifier, found end of \
                 file"
                    .to_string(),
            ),
        }
    }

    /// `State::Name` / `Dir::Name` — returns `Name`.
    fn path(&mut self, head: &str) -> Result<String, String> {
        self.expect_ident(head)?;
        self.expect_punct(':')?;
        self.expect_punct(':')?;
        Ok(self.ident()?.to_string())
    }
}

/// Parse the `TRANSITIONS` const out of `protocol.rs` source.
pub fn parse_table(src: &str) -> Result<ProtoTable, String> {
    let scanned = scan(src);
    let toks = &scanned.tokens;
    let at = toks
        .iter()
        .position(|t| t.is_ident("TRANSITIONS"))
        .ok_or("protocol table: no TRANSITIONS const in protocol.rs")?;
    // skip the type annotation to the initializer: `= &[`
    let mut cur = Cur { toks, i: at + 1 };
    while let Some(t) = cur.peek() {
        if t.is_punct('=') {
            break;
        }
        cur.i += 1;
    }
    cur.expect_punct('=')?;
    cur.expect_punct('&')?;
    cur.expect_punct('[')?;
    let mut rows = Vec::new();
    loop {
        match cur.peek() {
            Some(t) if t.is_punct(']') => break,
            Some(t) if t.is_punct(',') => {
                cur.i += 1;
                continue;
            }
            Some(_) => {}
            None => {
                return Err(
                    "protocol table: unterminated TRANSITIONS array"
                        .to_string(),
                )
            }
        }
        let line = cur.line();
        cur.expect_punct('(')?;
        let from = cur.path("State")?;
        cur.expect_punct(',')?;
        let dir = cur.path("Dir")?;
        cur.expect_punct(',')?;
        let tag = cur.path("wire")?;
        if !tag.starts_with("TAG_") {
            return Err(format!(
                "protocol table: row at line {line} names `{tag}`, \
                 expected a wire::TAG_* constant"
            ));
        }
        cur.expect_punct(',')?;
        let to = cur.path("State")?;
        cur.expect_punct(')')?;
        rows.push(ProtoRow { from, dir, tag, to });
    }
    if rows.is_empty() {
        return Err("protocol table: TRANSITIONS is empty".to_string());
    }
    Ok(ProtoTable { rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = "\
        pub const TRANSITIONS: &[(State, Dir, u8, State)] = &[\n\
            (State::Hello, Dir::ToMaster, wire::TAG_HELLO, State::Idle),\n\
            (State::Idle, Dir::ToWorker, wire::TAG_ROUND, State::Busy),\n\
            (State::Busy, Dir::ToMaster, wire::TAG_REPORT, State::Idle),\n\
        ];\n";

    #[test]
    fn parses_rows_in_order() {
        let t = parse_table(MINI).unwrap();
        assert_eq!(t.rows.len(), 3);
        assert_eq!(t.rows[0].from, "Hello");
        assert_eq!(t.rows[0].dir, "ToMaster");
        assert_eq!(t.rows[0].tag, "TAG_HELLO");
        assert_eq!(t.rows[0].to, "Idle");
        assert!(t.has_state("Busy"));
        assert!(!t.has_state("Draining"));
    }

    #[test]
    fn tag_queries_respect_states_and_direction() {
        let t = parse_table(MINI).unwrap();
        let states = vec!["Idle".to_string(), "Busy".to_string()];
        let both: Vec<_> = t.tags_in(&states).into_iter().collect();
        assert_eq!(both, vec!["TAG_REPORT", "TAG_ROUND"]);
        let to_master: Vec<_> =
            t.tags_in_dir(&states, "ToMaster").into_iter().collect();
        assert_eq!(to_master, vec!["TAG_REPORT"]);
    }

    #[test]
    fn rejects_missing_table_and_computed_rows() {
        assert!(parse_table("pub fn f() {}").is_err());
        let computed = "pub const TRANSITIONS: &[(State, Dir, u8, \
                        State)] = &[(State::A, Dir::ToMaster, \
                        my_tag(), State::B)];";
        assert!(parse_table(computed).is_err());
    }
}

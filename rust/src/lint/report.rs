//! Diagnostics: what a rule emits and how the binary prints it.

use std::fmt;

use crate::lint::TreeReport;
use crate::util::json::Json;

/// One rule violation (or annotation-grammar error) at a source line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    /// Rule id (`D1`, `D2`, `A1`, `P1`, `W1`, `S1`, `R1`, `D3`) or
    /// `LINT` for grammar errors.
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.msg
        )
    }
}

/// Render a batch, sorted by (file, line, rule) so output is stable
/// across directory-walk orders.
pub fn render(diags: &[Diagnostic]) -> String {
    let mut sorted: Vec<&Diagnostic> = diags.iter().collect();
    sorted.sort_by_key(|d| (d.file.clone(), d.line, d.rule));
    let mut out = String::new();
    for d in sorted {
        out.push_str(&d.to_string());
        out.push('\n');
    }
    out
}

/// Machine-readable report for `--format json`: a stable schema
/// (`version` bumps on breaking change) with the same (file, line,
/// rule) ordering as [`render`].
pub fn render_json(tree: &TreeReport) -> String {
    let mut sorted: Vec<&Diagnostic> = tree.diagnostics.iter().collect();
    sorted.sort_by_key(|d| (d.file.clone(), d.line, d.rule));
    let diags: Vec<Json> = sorted
        .iter()
        .map(|d| {
            Json::obj(vec![
                ("file", Json::Str(d.file.clone())),
                ("line", Json::Num(d.line as f64)),
                ("rule", Json::Str(d.rule.to_string())),
                ("msg", Json::Str(d.msg.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("files", Json::Num(tree.files.len() as f64)),
        (
            "suppressions",
            Json::Num(tree.suppressions.iter().sum::<usize>() as f64),
        ),
        ("violations", Json::Num(tree.diagnostics.len() as f64)),
        ("diagnostics", Json::Arr(diags)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_file_line_rule() {
        let d = Diagnostic {
            file: "src/a.rs".into(),
            line: 7,
            rule: "A1",
            msg: "allocation in hot path".into(),
        };
        assert_eq!(d.to_string(), "src/a.rs:7: [A1] allocation in hot path");
    }

    #[test]
    fn json_report_round_trips_and_sorts() {
        let mk = |f: &str, l: u32| Diagnostic {
            file: f.into(),
            line: l,
            rule: "P1",
            msg: "boom \"quoted\"".into(),
        };
        let tree = TreeReport {
            diagnostics: vec![mk("b.rs", 1), mk("a.rs", 2)],
            files: vec!["a.rs".into(), "b.rs".into()],
            suppressions: vec![1, 2],
        };
        let j = Json::parse(&render_json(&tree)).unwrap();
        assert_eq!(j.usize_of("version").unwrap(), 1);
        assert_eq!(j.usize_of("files").unwrap(), 2);
        assert_eq!(j.usize_of("suppressions").unwrap(), 3);
        assert_eq!(j.usize_of("violations").unwrap(), 2);
        let d = j.req("diagnostics").unwrap().as_arr().unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].str_of("file").unwrap(), "a.rs");
        assert_eq!(d[0].usize_of("line").unwrap(), 2);
        assert_eq!(d[1].str_of("rule").unwrap(), "P1");
        assert_eq!(d[1].str_of("msg").unwrap(), "boom \"quoted\"");
    }

    #[test]
    fn render_sorts_by_file_then_line() {
        let mk = |f: &str, l: u32| Diagnostic {
            file: f.into(),
            line: l,
            rule: "P1",
            msg: "x".into(),
        };
        let out = render(&[mk("b.rs", 1), mk("a.rs", 9), mk("a.rs", 2)]);
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[0].starts_with("a.rs:2"));
        assert!(lines[1].starts_with("a.rs:9"));
        assert!(lines[2].starts_with("b.rs:1"));
    }
}

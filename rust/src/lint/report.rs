//! Diagnostics: what a rule emits and how the binary prints it.

use std::fmt;

/// One rule violation (or annotation-grammar error) at a source line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    /// Rule id (`D1`, `D2`, `A1`, `P1`, `W1`) or `LINT` for grammar
    /// errors.
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.msg
        )
    }
}

/// Render a batch, sorted by (file, line, rule) so output is stable
/// across directory-walk orders.
pub fn render(diags: &[Diagnostic]) -> String {
    let mut sorted: Vec<&Diagnostic> = diags.iter().collect();
    sorted.sort_by_key(|d| (d.file.clone(), d.line, d.rule));
    let mut out = String::new();
    for d in sorted {
        out.push_str(&d.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_file_line_rule() {
        let d = Diagnostic {
            file: "src/a.rs".into(),
            line: 7,
            rule: "A1",
            msg: "allocation in hot path".into(),
        };
        assert_eq!(d.to_string(), "src/a.rs:7: [A1] allocation in hot path");
    }

    #[test]
    fn render_sorts_by_file_then_line() {
        let mk = |f: &str, l: u32| Diagnostic {
            file: f.into(),
            line: l,
            rule: "P1",
            msg: "x".into(),
        };
        let out = render(&[mk("b.rs", 1), mk("a.rs", 9), mk("a.rs", 2)]);
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[0].starts_with("a.rs:2"));
        assert!(lines[1].starts_with("a.rs:9"));
        assert!(lines[2].starts_with("b.rs:1"));
    }
}

//! Procedural image datasets with matched shapes to the paper's
//! benchmarks.
//!
//! Generation model per class `c`:
//!   prototype_c(h, w, ch) = sum_k a_k sin(2π(f_hk h + f_wk w) + φ_k)
//! — a smooth random field whose frequencies/phases are seeded by the
//! class id. A sample is the prototype under a random sub-pixel shift and
//! amplitude jitter plus i.i.d. pixel noise scaled by `difficulty`.
//! Classes are well-separated at difficulty 0 and overlap increasingly;
//! at the defaults a LeNet-class model reaches a few-percent error after
//! a few epochs while random init sits at chance — the regime the paper's
//! error curves live in.

use crate::data::DataConfig;
use crate::util::rng::Pcg64;

/// Dense image dataset (NHWC f32 in [-1, 1]) with int labels.
pub struct ImageDataset {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub num_classes: usize,
    pub images: Vec<f32>, // n * h * w * c
    pub labels: Vec<i32>,
}

impl ImageDataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image_numel(&self) -> usize {
        self.h * self.w * self.c
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let n = self.image_numel();
        &self.images[i * n..(i + 1) * n]
    }

    /// Take a subset by index (used by sharding).
    pub fn subset(&self, idx: &[usize]) -> ImageDataset {
        let n = self.image_numel();
        let mut images = Vec::with_capacity(idx.len() * n);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            images.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        ImageDataset {
            h: self.h,
            w: self.w,
            c: self.c,
            num_classes: self.num_classes,
            images,
            labels,
        }
    }
}

/// Class prototype: K low-frequency plane waves per channel.
struct Prototype {
    // per channel: (amp, fh, fw, phase) x K
    waves: Vec<[f32; 4]>,
    k: usize,
}

impl Prototype {
    fn new(class: usize, channels: usize, rng_root: &Pcg64) -> Self {
        let mut rng = rng_root.split(0x9000 + class as u64);
        let k = 4;
        let mut waves = Vec::with_capacity(channels * k);
        for _ in 0..channels * k {
            waves.push([
                0.5 + rng.next_f32(),            // amplitude
                rng.next_f32() * 3.0 + 0.5,      // fh cycles over image
                rng.next_f32() * 3.0 + 0.5,      // fw
                rng.next_f32() * std::f32::consts::TAU, // phase
            ]);
        }
        Prototype { waves, k }
    }

    /// Evaluate at (possibly shifted) normalized coordinates.
    fn eval(&self, ch: usize, u: f32, v: f32) -> f32 {
        let mut acc = 0.0;
        for i in 0..self.k {
            let [a, fh, fw, ph] = self.waves[ch * self.k + i];
            acc += a * (std::f32::consts::TAU * (fh * u + fw * v) + ph).sin();
        }
        acc / self.k as f32
    }
}

fn generate(
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    num_classes: usize,
    difficulty: f32,
    proto_rng: &Pcg64,
    rng: &mut Pcg64,
) -> ImageDataset {
    // Prototypes derive from proto_rng — the SAME generator for train and
    // val, so both sets share one class structure; `rng` drives only the
    // per-sample noise/deformation.
    let protos: Vec<Prototype> = (0..num_classes)
        .map(|cls| Prototype::new(cls, c, proto_rng))
        .collect();
    let mut images = Vec::with_capacity(n * h * w * c);
    let mut labels = Vec::with_capacity(n);
    let noise = 0.25 + 0.9 * difficulty;
    for _ in 0..n {
        let cls = rng.next_below(num_classes);
        let du = (rng.next_f32() - 0.5) * 0.2; // sub-pixel shift
        let dv = (rng.next_f32() - 0.5) * 0.2;
        let gain = 0.8 + 0.4 * rng.next_f32(); // amplitude jitter
        for yy in 0..h {
            for xx in 0..w {
                let u = yy as f32 / h as f32 + du;
                let v = xx as f32 / w as f32 + dv;
                for ch in 0..c {
                    let sig = protos[cls].eval(ch, u, v) * gain;
                    let x = sig + noise * rng.next_normal();
                    images.push(x.clamp(-2.0, 2.0));
                }
            }
        }
        labels.push(cls as i32);
    }
    ImageDataset {
        h,
        w,
        c,
        num_classes,
        images,
        labels,
    }
}

fn pair(
    cfg: &DataConfig,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    rng: &mut Pcg64,
) -> (ImageDataset, ImageDataset) {
    // One shared prototype bank: train/val are draws from the same
    // distribution (per-sample randomness uses independent streams).
    let proto_rng = rng.split(0);
    let mut train_rng = rng.split(1);
    let mut val_rng = rng.split(2);
    let train = generate(cfg.train, h, w, c, classes, cfg.difficulty,
                         &proto_rng, &mut train_rng);
    let val = generate(cfg.val, h, w, c, classes, cfg.difficulty,
                       &proto_rng, &mut val_rng);
    (train, val)
}

pub fn mnist_like(cfg: &DataConfig, rng: &mut Pcg64)
                  -> (ImageDataset, ImageDataset) {
    pair(cfg, 28, 28, 1, 10, rng)
}

pub fn cifar_like(cfg: &DataConfig, classes: usize, rng: &mut Pcg64)
                  -> (ImageDataset, ImageDataset) {
    pair(cfg, 32, 32, 3, classes, rng)
}

pub fn svhn_like(cfg: &DataConfig, rng: &mut Pcg64)
                 -> (ImageDataset, ImageDataset) {
    // SVHN: digits, higher intra-class variance -> bump difficulty.
    let mut c = cfg.clone();
    c.difficulty = (cfg.difficulty + 0.15).min(1.0);
    pair(&c, 32, 32, 3, 10, rng)
}

/// Flat gaussian-mixture features for the MLP quickstart ("images" of
/// shape [dim] stored as 1x1xdim so the container is uniform).
pub fn gauss_features(cfg: &DataConfig, rng: &mut Pcg64)
                      -> (ImageDataset, ImageDataset) {
    let dim = 32;
    let classes = 10;
    let mut centers = vec![0.0f32; classes * dim];
    let mut crng = rng.split(7);
    crng.fill_normal(&mut centers, 1.0);

    let gen = |n: usize, stream: u64| {
        let mut r = rng.split(stream);
        let noise = 0.6 + 1.2 * cfg.difficulty;
        let mut images = Vec::with_capacity(n * dim);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = r.next_below(classes);
            for d in 0..dim {
                images.push(centers[cls * dim + d] + noise * r.next_normal());
            }
            labels.push(cls as i32);
        }
        ImageDataset {
            h: 1,
            w: 1,
            c: dim,
            num_classes: classes,
            images,
            labels,
        }
    };
    (gen(cfg.train, 11), gen(cfg.val, 12))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DataConfig {
        DataConfig {
            train: 128,
            val: 32,
            difficulty: 0.3,
            seed: 1,
        }
    }

    #[test]
    fn shapes_match_benchmarks() {
        let mut rng = Pcg64::new(1, 1);
        let (t, _) = mnist_like(&cfg(), &mut rng);
        assert_eq!((t.h, t.w, t.c), (28, 28, 1));
        let (t, _) = cifar_like(&cfg(), 100, &mut rng);
        assert_eq!((t.h, t.w, t.c), (32, 32, 3));
        assert_eq!(t.num_classes, 100);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Pcg64::new(9, 9);
        let mut r2 = Pcg64::new(9, 9);
        let (a, _) = mnist_like(&cfg(), &mut r1);
        let (b, _) = mnist_like(&cfg(), &mut r2);
        assert_eq!(a.images[..100], b.images[..100]);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn classes_are_separated() {
        // nearest-prototype classification on clean prototypes should beat
        // chance by a wide margin at moderate difficulty
        let mut rng = Pcg64::new(3, 3);
        let (t, _) = mnist_like(&cfg(), &mut rng);
        // compute class means as stand-in prototypes
        let n = t.image_numel();
        let mut means = vec![0.0f64; 10 * n];
        let mut counts = [0usize; 10];
        for i in 0..t.len() {
            let cls = t.labels[i] as usize;
            counts[cls] += 1;
            for (j, &x) in t.image(i).iter().enumerate() {
                means[cls * n + j] += x as f64;
            }
        }
        for cls in 0..10 {
            if counts[cls] > 0 {
                for j in 0..n {
                    means[cls * n + j] /= counts[cls] as f64;
                }
            }
        }
        let mut correct = 0;
        for i in 0..t.len() {
            let img = t.image(i);
            let mut best = (f64::INFINITY, 0usize);
            for cls in 0..10 {
                let d: f64 = img
                    .iter()
                    .enumerate()
                    .map(|(j, &x)| {
                        let diff = x as f64 - means[cls * n + j];
                        diff * diff
                    })
                    .sum();
                if d < best.0 {
                    best = (d, cls);
                }
            }
            if best.1 == t.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / t.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy only {acc}");
    }

    #[test]
    fn val_shares_class_structure_with_train() {
        // class means computed on TRAIN must classify VAL above chance —
        // this is the regression test for the train/val prototype split
        // bug (val must be the same task, not a fresh one).
        let mut rng = Pcg64::new(13, 13);
        let c = DataConfig {
            train: 256,
            val: 128,
            difficulty: 0.3,
            seed: 13,
        };
        let (t, v) = mnist_like(&c, &mut rng);
        let n = t.image_numel();
        let mut means = vec![0.0f64; 10 * n];
        let mut counts = [0usize; 10];
        for i in 0..t.len() {
            let cls = t.labels[i] as usize;
            counts[cls] += 1;
            for (j, &x) in t.image(i).iter().enumerate() {
                means[cls * n + j] += x as f64;
            }
        }
        for cls in 0..10 {
            for j in 0..n {
                if counts[cls] > 0 {
                    means[cls * n + j] /= counts[cls] as f64;
                }
            }
        }
        let mut correct = 0;
        for i in 0..v.len() {
            let img = v.image(i);
            let mut best = (f64::INFINITY, 0usize);
            for cls in 0..10 {
                let d: f64 = img
                    .iter()
                    .enumerate()
                    .map(|(j, &x)| {
                        let diff = x as f64 - means[cls * n + j];
                        diff * diff
                    })
                    .sum();
                if d < best.0 {
                    best = (d, cls);
                }
            }
            if best.1 == v.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / v.len() as f64;
        assert!(acc > 0.4, "train-means accuracy on val only {acc}");
    }

    #[test]
    fn subset_picks_rows() {
        let mut rng = Pcg64::new(4, 4);
        let (t, _) = mnist_like(&cfg(), &mut rng);
        let s = t.subset(&[3, 5]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.image(0), t.image(3));
        assert_eq!(s.labels[1], t.labels[5]);
    }

    #[test]
    fn values_bounded() {
        let mut rng = Pcg64::new(5, 5);
        let (t, _) = cifar_like(&cfg(), 10, &mut rng);
        assert!(t.images.iter().all(|x| x.abs() <= 2.0));
    }
}

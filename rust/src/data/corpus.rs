//! Synthetic character corpus for the transformer end-to-end example.
//!
//! Order-2 Markov chain over a 64-symbol alphabet with a handful of
//! embedded motifs (repeated multi-token phrases). The chain gives the LM
//! local statistics to learn quickly; the motifs give longer-range
//! structure so attention has something to do — loss drops well below the
//! unigram entropy within a few hundred steps, which is what the e2e
//! example logs.

use crate::data::DataConfig;
use crate::util::rng::Pcg64;

pub const VOCAB: usize = 64;
const MOTIFS: usize = 8;
const MOTIF_LEN: usize = 12;

/// Token stream + window sampler.
pub struct CorpusDataset {
    pub tokens: Vec<i32>,
    pub seq_len: usize,
    /// nominal number of windows per epoch (sampler is random-offset)
    pub windows: usize,
}

impl CorpusDataset {
    pub fn len(&self) -> usize {
        self.windows
    }

    pub fn is_empty(&self) -> bool {
        self.windows == 0
    }

    /// Draw a window start offset — the *only* RNG consumption of
    /// [`CorpusDataset::sample_window`]. The batcher's resume replay
    /// (`Batcher::skip_batches`) calls this too, so the draw schedule
    /// cannot diverge between the real and skip paths.
    pub fn draw_start(&self, t: usize, rng: &mut Pcg64) -> usize {
        let max_start = self.tokens.len() - t - 1;
        rng.next_below(max_start)
    }

    /// Sample an (input, target) window pair of length `t`.
    pub fn sample_window(&self, t: usize, rng: &mut Pcg64)
                         -> (Vec<i32>, Vec<i32>) {
        let s = self.draw_start(t, rng);
        (
            self.tokens[s..s + t].to_vec(),
            self.tokens[s + 1..s + t + 1].to_vec(),
        )
    }
}

fn build_chain(rng: &mut Pcg64) -> Vec<Vec<(i32, f32)>> {
    // sparse transition table: for each (prev) context, a few favored
    // successors — order-1 for memory economy, motifs add the long range.
    let mut table = Vec::with_capacity(VOCAB);
    for _ in 0..VOCAB {
        let k = 4 + rng.next_below(4);
        let mut succ = Vec::with_capacity(k);
        let mut total = 0.0f32;
        for _ in 0..k {
            let w = rng.next_f32() + 0.1;
            succ.push((rng.next_below(VOCAB) as i32, w));
            total += w;
        }
        for s in succ.iter_mut() {
            s.1 /= total;
        }
        table.push(succ);
    }
    table
}

fn gen_stream(n_tokens: usize, rng: &mut Pcg64) -> Vec<i32> {
    let chain = build_chain(&mut rng.split(1));
    let mut motif_rng = rng.split(2);
    let motifs: Vec<Vec<i32>> = (0..MOTIFS)
        .map(|_| {
            (0..MOTIF_LEN)
                .map(|_| motif_rng.next_below(VOCAB) as i32)
                .collect()
        })
        .collect();

    let mut out = Vec::with_capacity(n_tokens);
    let mut cur = rng.next_below(VOCAB) as i32;
    while out.len() < n_tokens {
        if rng.next_f32() < 0.05 {
            // drop in a motif
            let m = &motifs[rng.next_below(MOTIFS)];
            out.extend_from_slice(m);
            cur = *m.last().unwrap();
            continue;
        }
        let succ = &chain[cur as usize];
        let mut u = rng.next_f32();
        let mut next = succ[0].0;
        for &(tok, p) in succ {
            if u < p {
                next = tok;
                break;
            }
            u -= p;
        }
        out.push(next);
        cur = next;
    }
    out.truncate(n_tokens);
    out
}

pub fn build(cfg: &DataConfig, rng: &mut Pcg64)
             -> (CorpusDataset, CorpusDataset) {
    let train_tokens = (cfg.train * 80).max(4096);
    let val_tokens = (cfg.val * 80).max(2048);
    let stream = gen_stream(train_tokens + val_tokens, &mut rng.split(3));
    let (a, b) = stream.split_at(train_tokens);
    (
        CorpusDataset {
            tokens: a.to_vec(),
            seq_len: 64,
            windows: cfg.train,
        },
        CorpusDataset {
            tokens: b.to_vec(),
            seq_len: 64,
            windows: cfg.val,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_shift_by_one() {
        let mut rng = Pcg64::new(1, 2);
        let cfg = DataConfig {
            train: 16,
            val: 8,
            ..Default::default()
        };
        let (t, _) = build(&cfg, &mut rng);
        let (x, y) = t.sample_window(32, &mut rng);
        assert_eq!(x.len(), 32);
        assert_eq!(y.len(), 32);
        assert_eq!(x[1..], y[..31]); // target is input shifted by one
    }

    #[test]
    fn tokens_in_vocab() {
        let mut rng = Pcg64::new(3, 4);
        let cfg = DataConfig::default();
        let (t, v) = build(&cfg, &mut rng);
        assert!(t.tokens.iter().all(|&x| (0..VOCAB as i32).contains(&x)));
        assert!(v.tokens.iter().all(|&x| (0..VOCAB as i32).contains(&x)));
    }

    #[test]
    fn stream_not_constant() {
        let mut rng = Pcg64::new(5, 6);
        let s = gen_stream(1000, &mut rng);
        let first = s[0];
        assert!(s.iter().any(|&x| x != first));
    }
}

//! Dataset sharding for §5 ("Splitting the data between replicas").
//!
//! The paper splits the training set evenly so each replica `a` sees only
//! its shard `ξ^a`, with every sample in at least one shard; the proximal
//! term is the only channel through which gradients on `ξ^b` reach
//! replica `a`. `split_shards` reproduces that protocol: a seeded shuffle
//! followed by contiguous slicing into `n` near-equal parts.

use crate::data::synth_images::ImageDataset;
use crate::util::rng::Pcg64;

/// Split `ds` into `n` disjoint shards covering every example.
pub fn split_shards(ds: &ImageDataset, n: usize, seed: u64)
                    -> Vec<ImageDataset> {
    assert!(n >= 1);
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Pcg64::new(seed, SHARD_STREAM);
    rng.shuffle(&mut idx);
    let base = ds.len() / n;
    let rem = ds.len() % n;
    let mut shards = Vec::with_capacity(n);
    let mut start = 0;
    for a in 0..n {
        let take = base + usize::from(a < rem);
        shards.push(ds.subset(&idx[start..start + take]));
        start += take;
    }
    shards
}

/// RNG stream id reserved for shard shuffles.
const SHARD_STREAM: u64 = 0x5a4d;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_images, DataConfig};

    fn dataset(n: usize) -> ImageDataset {
        let mut rng = Pcg64::new(7, 7);
        let cfg = DataConfig {
            train: n,
            val: 1,
            difficulty: 0.3,
            seed: 7,
        };
        synth_images::mnist_like(&cfg, &mut rng).0
    }

    #[test]
    fn covers_everything_disjointly() {
        let ds = dataset(103);
        let shards = split_shards(&ds, 3, 1);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 103);
        // sizes near-equal
        for s in &shards {
            assert!((s.len() as i64 - 34).abs() <= 1);
        }
    }

    #[test]
    fn single_shard_is_whole_set() {
        let ds = dataset(32);
        let shards = split_shards(&ds, 1, 1);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].len(), 32);
    }

    #[test]
    fn deterministic() {
        let ds = dataset(50);
        let a = split_shards(&ds, 4, 9);
        let b = split_shards(&ds, 4, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.labels, y.labels);
        }
    }
}

//! Minibatch sampling + train-time augmentation.
//!
//! Augmentation matches the paper's CIFAR pipeline: random mirror flips
//! (p=0.5) and random crops after 4-pixel padding (§4.3). MNIST-like data
//! is used raw (the paper does no MNIST preprocessing). The batcher emits
//! flat host buffers ready to become `xla::Literal`s.

use crate::data::corpus::CorpusDataset;
use crate::data::synth_images::ImageDataset;
use crate::data::Dataset;
use crate::util::rng::Pcg64;

/// One host-side minibatch.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Image/feature data (empty for token batches).
    pub x_f32: Vec<f32>,
    /// Token data (empty for image batches).
    pub x_i32: Vec<i32>,
    pub y: Vec<i32>,
    pub n: usize,
}

/// Augmentation switches.
#[derive(Clone, Copy, Debug)]
pub struct Augment {
    pub mirror: bool,
    pub crop_pad: usize, // 0 = off
}

impl Augment {
    pub fn none() -> Self {
        Augment {
            mirror: false,
            crop_pad: 0,
        }
    }

    pub fn cifar() -> Self {
        Augment {
            mirror: true,
            crop_pad: 4,
        }
    }
}

/// Samples minibatches (with replacement across epochs, shuffled within).
pub struct Batcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    seq_len: usize,
    augment: Augment,
    order: Vec<usize>,
    cursor: usize,
    rng: Pcg64,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, seq_len: usize,
               augment: Augment, seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64::new(seed, stream);
        let mut order: Vec<usize> = (0..ds.len()).collect();
        rng.shuffle(&mut order);
        Batcher {
            ds,
            batch,
            seq_len,
            augment,
            order,
            cursor: 0,
            rng,
        }
    }

    /// Minibatches per epoch (the paper's B in the scoping schedule (9)).
    pub fn batches_per_epoch(&self) -> usize {
        (self.ds.len() / self.batch).max(1)
    }

    /// Next training minibatch (reshuffles at epoch boundaries).
    pub fn next(&mut self) -> Batch {
        match self.ds {
            Dataset::Image(img) => self.next_image(img),
            Dataset::Corpus(c) => self.next_tokens(c),
        }
    }

    fn next_index(&mut self) -> usize {
        if self.cursor >= self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
        }
        let i = self.order[self.cursor];
        self.cursor += 1;
        i
    }

    fn next_image(&mut self, img: &ImageDataset) -> Batch {
        let numel = img.image_numel();
        let mut x = Vec::with_capacity(self.batch * numel);
        let mut y = Vec::with_capacity(self.batch);
        let aug = self.augment;
        for _ in 0..self.batch {
            let i = self.next_index();
            let src = img.image(i);
            let draw = draw_augment(&aug, &mut self.rng);
            augment_into(src, img.h, img.w, img.c, draw, &mut x);
            y.push(img.labels[i]);
        }
        Batch {
            x_f32: x,
            x_i32: Vec::new(),
            y,
            n: self.batch,
        }
    }

    fn next_tokens(&mut self, c: &CorpusDataset) -> Batch {
        let t = self.seq_len;
        let mut x = Vec::with_capacity(self.batch * t);
        let mut y = Vec::with_capacity(self.batch * t);
        for _ in 0..self.batch {
            let (xs, ys) = c.sample_window(t, &mut self.rng);
            x.extend_from_slice(&xs);
            y.extend_from_slice(&ys);
        }
        Batch {
            x_f32: Vec::new(),
            x_i32: x,
            y,
            n: self.batch,
        }
    }

    /// Fast-forward past `n` training batches without assembling them:
    /// replays exactly the RNG draws `next` would make (shuffles at
    /// epoch boundaries, per-example augmentation draws, corpus window
    /// offsets), so a resumed run's data/augment streams continue
    /// bit-exactly from where the checkpointed run stopped. Consumes
    /// the draws through the same helpers the real path uses
    /// ([`draw_augment`], [`CorpusDataset::draw_start`]) — the two
    /// paths cannot desynchronize — and is pinned by
    /// `skip_matches_consumed_batches`.
    pub fn skip_batches(&mut self, n: u64) {
        for _ in 0..n {
            match self.ds {
                Dataset::Image(_) => {
                    let aug = self.augment;
                    for _ in 0..self.batch {
                        let _ = self.next_index();
                        let _ = draw_augment(&aug, &mut self.rng);
                    }
                }
                Dataset::Corpus(c) => {
                    for _ in 0..self.batch {
                        let _ = c.draw_start(self.seq_len, &mut self.rng);
                    }
                }
            }
        }
    }

    /// Deterministic full sweep for evaluation (no augmentation); returns
    /// complete batches only (callers size val sets as a multiple).
    pub fn eval_batches(&self) -> Vec<Batch> {
        match self.ds {
            Dataset::Image(img) => {
                let numel = img.image_numel();
                let nb = img.len() / self.batch;
                (0..nb)
                    .map(|b| {
                        let mut x = Vec::with_capacity(self.batch * numel);
                        let mut y = Vec::with_capacity(self.batch);
                        for i in b * self.batch..(b + 1) * self.batch {
                            x.extend_from_slice(img.image(i));
                            y.push(img.labels[i]);
                        }
                        Batch {
                            x_f32: x,
                            x_i32: Vec::new(),
                            y,
                            n: self.batch,
                        }
                    })
                    .collect()
            }
            Dataset::Corpus(c) => {
                let t = self.seq_len;
                let nb = (c.windows / self.batch).max(1);
                let mut rng = Pcg64::new(0xea1, 0);
                (0..nb)
                    .map(|_| {
                        let mut x = Vec::with_capacity(self.batch * t);
                        let mut y = Vec::with_capacity(self.batch * t);
                        for _ in 0..self.batch {
                            let (xs, ys) = c.sample_window(t, &mut rng);
                            x.extend_from_slice(&xs);
                            y.extend_from_slice(&ys);
                        }
                        Batch {
                            x_f32: Vec::new(),
                            x_i32: x,
                            y,
                            n: self.batch,
                        }
                    })
                    .collect()
            }
        }
    }
}

/// One example's augmentation parameters, drawn by [`draw_augment`].
#[derive(Clone, Copy, Debug)]
struct AugDraw {
    flip: bool,
    dy: i64,
    dx: i64,
}

/// Draw the per-example augmentation parameters. This is the *only*
/// RNG consumption of the augmentation path: `Batcher::next` and
/// `Batcher::skip_batches` both go through it, so the real and
/// resume-replay draw schedules cannot desynchronize.
fn draw_augment(aug: &Augment, rng: &mut Pcg64) -> AugDraw {
    let flip = aug.mirror && rng.next_f32() < 0.5;
    let (dy, dx) = if aug.crop_pad > 0 {
        let p = aug.crop_pad as i64;
        (
            rng.next_below(2 * aug.crop_pad + 1) as i64 - p,
            rng.next_below(2 * aug.crop_pad + 1) as i64 - p,
        )
    } else {
        (0, 0)
    };
    AugDraw { flip, dy, dx }
}

/// Apply mirror/crop augmentation, appending HWC pixels to `out`.
fn augment_into(
    src: &[f32],
    h: usize,
    w: usize,
    c: usize,
    draw: AugDraw,
    out: &mut Vec<f32>,
) {
    let AugDraw { flip, dy, dx } = draw;
    if !flip && dy == 0 && dx == 0 {
        out.extend_from_slice(src);
        return;
    }
    for yy in 0..h as i64 {
        for xx in 0..w as i64 {
            let sy = yy + dy;
            let sx = if flip { w as i64 - 1 - xx } else { xx } + dx;
            if sy < 0 || sy >= h as i64 || sx < 0 || sx >= w as i64 {
                // zero padding outside the crop
                for _ in 0..c {
                    out.push(0.0);
                }
            } else {
                let base = (sy as usize * w + sx as usize) * c;
                out.extend_from_slice(&src[base..base + c]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{build, DataConfig};

    fn image_ds() -> Dataset {
        let cfg = DataConfig {
            train: 64,
            val: 32,
            ..Default::default()
        };
        build("synth_mnist", &cfg).unwrap().0
    }

    #[test]
    fn batch_shapes() {
        let ds = image_ds();
        let mut b = Batcher::new(&ds, 16, 0, Augment::none(), 1, 0);
        let batch = b.next();
        assert_eq!(batch.n, 16);
        assert_eq!(batch.x_f32.len(), 16 * 28 * 28);
        assert_eq!(batch.y.len(), 16);
        assert_eq!(b.batches_per_epoch(), 4);
    }

    #[test]
    fn epoch_covers_all_examples() {
        let ds = image_ds();
        let mut b = Batcher::new(&ds, 16, 0, Augment::none(), 1, 0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let batch = b.next();
            for i in 0..batch.n {
                // identify example by its first pixel + label (images are
                // continuous-valued so collisions are improbable)
                let key = (batch.x_f32[i * 784].to_bits(), batch.y[i]);
                seen.insert(key);
            }
        }
        assert!(seen.len() > 60, "epoch should cover most examples");
    }

    #[test]
    fn augmentation_changes_pixels() {
        let ds = image_ds();
        let mut plain = Batcher::new(&ds, 32, 0, Augment::none(), 2, 0);
        let mut aug = Batcher::new(&ds, 32, 0, Augment::cifar(), 2, 0);
        let a = plain.next();
        let b = aug.next();
        assert_ne!(a.x_f32, b.x_f32);
        assert_eq!(a.y, b.y); // same example order, same labels
    }

    #[test]
    fn eval_batches_deterministic() {
        let ds = image_ds();
        let b = Batcher::new(&ds, 16, 0, Augment::none(), 3, 0);
        let e1 = b.eval_batches();
        let e2 = b.eval_batches();
        assert_eq!(e1.len(), 4);
        assert_eq!(e1[0].x_f32, e2[0].x_f32);
    }

    /// Resume contract: `skip_batches(n)` leaves the batcher in exactly
    /// the state `n` real draws would — the (n+1)-th batch matches
    /// bit-for-bit, including augmentation RNG draws and epoch-boundary
    /// reshuffles (n=5 crosses the 4-batch epoch).
    #[test]
    fn skip_matches_consumed_batches() {
        let ds = image_ds();
        for aug in [Augment::none(), Augment::cifar()] {
            for n in [0u64, 1, 3, 5, 9] {
                let mut consumed = Batcher::new(&ds, 16, 0, aug, 7, 3);
                for _ in 0..n {
                    let _ = consumed.next();
                }
                let mut skipped = Batcher::new(&ds, 16, 0, aug, 7, 3);
                skipped.skip_batches(n);
                let a = consumed.next();
                let b = skipped.next();
                assert_eq!(a.y, b.y, "labels diverged at n={n}");
                assert_eq!(a.x_f32, b.x_f32, "pixels diverged at n={n}");
            }
        }
    }

    #[test]
    fn skip_matches_consumed_token_batches() {
        let cfg = DataConfig {
            train: 32,
            val: 16,
            ..Default::default()
        };
        let (ds, _) = build("synth_corpus", &cfg).unwrap();
        let mut consumed = Batcher::new(&ds, 4, 32, Augment::none(), 5, 1);
        for _ in 0..6 {
            let _ = consumed.next();
        }
        let mut skipped = Batcher::new(&ds, 4, 32, Augment::none(), 5, 1);
        skipped.skip_batches(6);
        assert_eq!(consumed.next().x_i32, skipped.next().x_i32);
    }

    #[test]
    fn token_batches() {
        let cfg = DataConfig {
            train: 32,
            val: 16,
            ..Default::default()
        };
        let (t, _) = build("synth_corpus", &cfg).unwrap();
        let mut b = Batcher::new(&t, 4, 32, Augment::none(), 1, 0);
        let batch = b.next();
        assert_eq!(batch.x_i32.len(), 4 * 32);
        assert_eq!(batch.y.len(), 4 * 32);
        assert!(batch.x_f32.is_empty());
    }
}

//! Data substrate: synthetic datasets, sharding (§5), augmentation and
//! batching.
//!
//! The paper's benchmarks (MNIST/CIFAR-10/CIFAR-100/SVHN) are not
//! downloadable in this offline environment, so `synth_images` builds
//! procedural stand-ins with matched shapes and a controllable difficulty
//! (DESIGN.md §4): per-class low-frequency prototypes + instance
//! deformations + pixel noise. They are genuinely learnable — error
//! curves show the same qualitative dynamics (fast early progress,
//! plateau, sensitivity to LR drops) the paper's figures rely on.

pub mod batcher;
pub mod corpus;
pub mod shard;
pub mod synth_images;

pub use batcher::{Batch, Batcher};
pub use shard::split_shards;
pub use synth_images::ImageDataset;

use anyhow::{bail, Result};

use crate::util::rng::Pcg64;

/// A dataset the coordinator can batch from: images or token windows.
pub enum Dataset {
    Image(synth_images::ImageDataset),
    Corpus(corpus::CorpusDataset),
}

impl Dataset {
    pub fn len(&self) -> usize {
        match self {
            Dataset::Image(d) => d.len(),
            Dataset::Corpus(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Options for dataset synthesis.
#[derive(Clone, Debug)]
pub struct DataConfig {
    /// Training examples (corpus: number of sampled windows per "epoch").
    pub train: usize,
    /// Held-out validation examples.
    pub val: usize,
    /// Label noise / intrinsic difficulty in [0, 1].
    pub difficulty: f32,
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            train: 4096,
            val: 1024,
            difficulty: 0.35,
            seed: 0,
        }
    }
}

/// Build the train+val pair for a manifest dataset tag
/// (`synth_mnist`, `synth_cifar10`, `synth_cifar100`, `synth_svhn`,
/// `synth_gauss`, `synth_corpus`).
pub fn build(tag: &str, cfg: &DataConfig) -> Result<(Dataset, Dataset)> {
    let mut rng = Pcg64::new(cfg.seed, 0xda7a);
    let (train, val) = match tag {
        "synth_mnist" => synth_images::mnist_like(cfg, &mut rng),
        "synth_cifar10" => synth_images::cifar_like(cfg, 10, &mut rng),
        "synth_cifar100" => synth_images::cifar_like(cfg, 100, &mut rng),
        "synth_svhn" => synth_images::svhn_like(cfg, &mut rng),
        "synth_gauss" => synth_images::gauss_features(cfg, &mut rng),
        "synth_corpus" => {
            let (t, v) = corpus::build(cfg, &mut rng);
            return Ok((Dataset::Corpus(t), Dataset::Corpus(v)));
        }
        other => bail!("unknown dataset tag {other:?}"),
    };
    Ok((Dataset::Image(train), Dataset::Image(val)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_all_tags() {
        let cfg = DataConfig {
            train: 64,
            val: 32,
            ..Default::default()
        };
        for tag in [
            "synth_mnist",
            "synth_cifar10",
            "synth_cifar100",
            "synth_svhn",
            "synth_gauss",
            "synth_corpus",
        ] {
            let (t, v) = build(tag, &cfg).unwrap();
            assert_eq!(t.len(), 64, "{tag}");
            assert_eq!(v.len(), 32, "{tag}");
        }
        assert!(build("nope", &cfg).is_err());
    }
}

//! `parle` CLI — the L3 entrypoint.
//!
//! ```text
//! parle train --model wrn_cifar10 --algo parle [--set key=value ...]
//! parle experiment <fig1|fig2|...|table1|table2|comm|ablate-*|all>
//! parle perfmodel                  # paper-scale Table-1 time columns
//! parle list                       # models + experiments
//! parle selftest                   # quick runtime round-trip check
//! ```

use anyhow::{bail, Context, Result};

use parle::config::{Algo, RunConfig};
use parle::coordinator::train;
use parle::experiments::{run_experiment, ExpCtx, EXPERIMENTS};
use parle::runtime::Session;
use parle::util::logging::{set_level, Level};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut pos = Vec::new();
    let mut flags: Vec<(String, String)> = Vec::new();
    let mut sets: Vec<(String, String)> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if a == "--set" {
            let kv = args
                .get(i + 1)
                .context("--set needs key=value")?;
            let (k, v) = kv
                .split_once('=')
                .context("--set needs key=value")?;
            sets.push((k.to_string(), v.to_string()));
            i += 2;
        } else if let Some(name) = a.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                flags.push((k.to_string(), v.to_string()));
                i += 1;
            } else if name == "quick" || name == "verbose" || name == "quiet"
            {
                flags.push((name.to_string(), "true".to_string()));
                i += 1;
            } else {
                let v = args
                    .get(i + 1)
                    .with_context(|| format!("--{name} needs a value"))?;
                flags.push((name.to_string(), v.clone()));
                i += 2;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    let flag = |k: &str| -> Option<&str> {
        flags.iter().rev().find(|(f, _)| f == k).map(|(_, v)| v.as_str())
    };

    if flag("quiet").is_some() {
        set_level(Level::Warn);
    } else if flag("verbose").is_some() {
        set_level(Level::Debug);
    }

    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "train" => {
            let model = flag("model").unwrap_or("mlp_synth").to_string();
            let algo = Algo::parse(flag("algo").unwrap_or("parle"))?;
            let mut cfg = RunConfig::new(&model, algo);
            if let Some(dir) = flag("artifacts") {
                cfg.artifacts_dir = dir.to_string();
            }
            for (k, v) in &sets {
                cfg.set(k, v)?;
            }
            if let Some(mode) = flag("comm-mode") {
                cfg.comm_mode = parle::config::CommMode::parse(mode)?;
            }
            if let Some(t) = flag("transport") {
                cfg.transport = parle::config::TransportCfg::parse(t)?;
            }
            if let Some(b) = flag("reduce-bucket-bytes") {
                cfg.reduce_bucket_bytes = b.parse().context(
                    "--reduce-bucket-bytes needs a byte count (0 = \
                     whole-vector rounds)",
                )?;
            }
            if let Some(c) = flag("wire-codec") {
                cfg.wire_codec = parle::config::WireCodec::parse(c)?;
            }
            if let Some(addr) = flag("listen") {
                cfg.listen = Some(addr.to_string());
            }
            if let Some(s) = flag("heartbeat-every") {
                cfg.heartbeat_secs = s.parse().context(
                    "--heartbeat-every needs seconds (0 = no pings)",
                )?;
            }
            if let Some(s) = flag("evict-after") {
                cfg.evict_after_secs = s.parse().context(
                    "--evict-after needs seconds (0 = fail-stop)",
                )?;
            }
            if let Some(s) = flag("master-silence") {
                cfg.master_silence_secs = s.parse().context(
                    "--master-silence needs seconds (0 = wait forever)",
                )?;
            }
            if let Some(path) = flag("resume") {
                cfg.resume_from = Some(path.to_string());
            }
            match flag("role").unwrap_or("master") {
                "worker" => {
                    // distributed worker process: serve replica legs
                    // against a remote master; no record/checkpoint of
                    // its own (the master owns the run's outputs)
                    cfg.transport = parle::config::TransportCfg::Tcp;
                    let connect = flag("connect").context(
                        "--role worker needs --connect host:port",
                    )?;
                    return parle::coordinator::serve_worker(&cfg, connect);
                }
                "master" => {}
                other => bail!("unknown --role {other:?} (master|worker)"),
            }
            let label = flag("label").unwrap_or("train").to_string();
            let out = train(&cfg, &label)?;
            out.record.save(flag("out").unwrap_or("runs"))?;
            if let Some(ck) = flag("checkpoint") {
                parle::coordinator::Checkpoint::new(&cfg.model,
                                                    out.final_params.clone())
                    .with("val_err", out.record.final_val_err)
                    .with("epochs", cfg.epochs)
                    .save(ck)?;
                println!("checkpoint written to {ck}");
            }
            println!("{}", out.record.summary());
            Ok(())
        }
        "experiment" | "exp" => {
            let name = pos
                .get(1)
                .context("usage: parle experiment <name>")?;
            let ctx = ExpCtx {
                artifacts_dir: flag("artifacts")
                    .unwrap_or("artifacts")
                    .to_string(),
                out_dir: flag("out").unwrap_or("runs").to_string(),
                quick: flag("quick").is_some(),
                seed: flag("seed").unwrap_or("42").parse()?,
            };
            std::fs::create_dir_all(&ctx.out_dir)?;
            run_experiment(name, &ctx)
        }
        "perfmodel" => {
            parle::experiments::table1::paper_scale_times();
            Ok(())
        }
        "list" => {
            let dir = flag("artifacts").unwrap_or("artifacts");
            println!("experiments:");
            for (name, desc) in EXPERIMENTS {
                println!("  {name:<18} {desc}");
            }
            match Session::open(dir) {
                Ok(s) => {
                    println!("\nmodels in {dir}:");
                    for (name, mm) in &s.manifest.models {
                        println!(
                            "  {name:<16} P={:<9} batch={:<4} L={} \
                             dataset={}",
                            mm.param_count, mm.batch, mm.scan_l, mm.dataset
                        );
                    }
                }
                Err(e) => println!("\n(no artifacts: {e})"),
            }
            Ok(())
        }
        "selftest" => {
            let dir = flag("artifacts").unwrap_or("artifacts");
            selftest(dir)
        }
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "\
parle — Rust+JAX+Pallas reproduction of 'Parle: parallelizing SGD'

USAGE:
  parle train --model <zoo> --algo <parle|elastic|entropy|sgd|sgd-dp>
              [--set key=value ...] [--label name] [--out runs]
              [--comm-mode sync|async] [--resume <ckpt>]
              [--transport tcp --role master|worker
               --listen host:port | --connect host:port]
  parle experiment <name|all> [--quick] [--out runs] [--seed N]
  parle perfmodel
  parle list
  parle selftest

COMMUNICATION:
  --comm-mode sync           the paper's synchronous round barrier
                             (default; deterministic given a seed)
  --comm-mode async          asynchronous elastic updates: replicas run
                             their L-step legs at their own pace, the
                             master applies eq. (5)-style partial
                             updates per arriving report
  --set max_staleness=K      async only: a replica may run at most K
                             rounds ahead of the slowest one (default
                             4; 0 = lockstep)
  --set async_lr_rescale=1   async sgd-dp only: divide the per-gradient
                             LR by n replicas (Downpour effective-batch
                             correction) so sync-tuned schedules
                             transfer
  --reduce-bucket-bytes N    sync only: stream each round's parameters
                             in N-byte buckets so the master reduces
                             early buckets while later ones are still
                             in flight (default 16 MiB; 0 = legacy
                             whole-vector rounds). Bit-identical results
                             for every value, on both transports

DISTRIBUTED (multi-process, TCP):
  --transport tcp            run the fabric over a length-prefixed TCP
                             wire instead of in-process channels;
                             sync-mode results are bit-identical to the
                             default transport. Simulated --set comm=
                             profiles are skipped (wire time is real).
  --role master --listen A   the master binds A (host:port) and waits
                             for `replicas` workers to connect, then
                             trains as usual and owns all outputs
  --role worker --connect A  serve one replica (slot assigned by the
                             master at connect) with the SAME model/
                             algo/seed/--set flags as the master;
                             exits when the master finishes
  --wire-codec C             payload transform for TCP round traffic
                             (both ends must agree; the handshake
                             refuses a mismatch). raw (default,
                             bit-identical wire), bf16 | f16 (2-byte
                             floats, report leg error-feedback
                             compensated), topk<K> (ship the K-fraction
                             largest report entries, e.g. topk0.01;
                             broadcast goes bf16), delta (XOR-delta the
                             broadcast against the previous round;
                             trajectory identical to raw), delta+bf16
                             (both). Excluded from the replay
                             fingerprint; raw and delta replay
                             bit-identically
  --evict-after S            master: evict a replica silent for S
                             seconds instead of fail-stopping the run —
                             its shard is parked, barriers shrink to the
                             live members, and the listener keeps
                             admitting fingerprint-matched late joiners
                             mid-run (default 0 = classic fail-stop)
  --heartbeat-every S        worker: ping the master after S seconds of
                             idleness between round legs so long legs
                             don't read as death (default 2; must be
                             shorter than --evict-after; 0 = no pings)
  --master-silence S         worker: fail with a typed diagnosis once
                             the master has been silent S seconds
                             (default 0 = wait forever)

CHECKPOINT/RESUME:
  --set checkpoint_every=N   write a full-state checkpoint every N
                             communication rounds (default 0 = never)
  --set checkpoint_path=P    destination; a {round} placeholder keeps
                             per-round history (default
                             checkpoints/<label>.ck, overwritten)
  --resume <ckpt>            continue a run from such a checkpoint; a
                             sync-mode resume reproduces the
                             uninterrupted run's final params and curve
                             (async resumes continue each replica at its
                             own round stamp but are not bit-replayable)
  --set overlap_eval=false   evaluate inside the round barrier instead
                             of on the dedicated eval thread

Run `make artifacts` first to AOT-compile the models.";

/// Round-trip check: init + inner steps + eval on the smallest model.
fn selftest(artifacts: &str) -> Result<()> {
    let mut cfg = RunConfig::new("mlp_synth", Algo::Parle);
    cfg.artifacts_dir = artifacts.to_string();
    cfg.replicas = 2;
    cfg.epochs = 0.5;
    cfg.data.train = 512;
    cfg.data.val = 256;
    cfg.eval_every_rounds = 1;
    cfg.l_steps = 4;
    let out = train(&cfg, "selftest")?;
    let err = out.record.final_val_err;
    println!("selftest: val err {:.1}% after half an epoch", err * 100.0);
    if !(err < 0.9) {
        bail!("selftest: error did not drop below chance ({err})");
    }
    println!("selftest OK");
    Ok(())
}

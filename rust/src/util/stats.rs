//! Descriptive statistics over f64 samples (means, stddev, quantiles) —
//! used by the bench harness and the experiment reports ("mean ± std over
//! 3 runs" exactly like the paper's tables).

/// Accumulating sample container.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        Stats {
            samples: xs.to_vec(),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (n-1 denominator; 0 for n <= 1).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n <= 1 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - m) * (x - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Smallest non-NaN sample (`f64::min` skips NaN operands, so a NaN
    /// timing sample cannot poison the result).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest non-NaN sample (NaN-tolerant, like [`Stats::min`]).
    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated quantile, q in [0, 1]. NaN samples are
    /// ignored, matching `min`/`max` (a `partial_cmp().unwrap()` sort
    /// used to panic on them); NaN only when no real samples exist.
    pub fn quantile(&self, q: f64) -> f64 {
        let mut sorted: Vec<f64> = self
            .samples
            .iter()
            .copied()
            .filter(|x| !x.is_nan())
            .collect();
        if sorted.is_empty() {
            return f64::NAN;
        }
        sorted.sort_by(f64::total_cmp);
        let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = pos - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// "4.08 ± 0.90" formatting used in reports.
    pub fn mean_pm_std(&self, digits: usize) -> String {
        format!(
            "{:.d$} ± {:.d$}",
            self.mean(),
            self.std(),
            d = digits
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let s = Stats::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let s = Stats::from_slice(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 4.0);
        assert!((s.median() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    /// Regression: a NaN sample (e.g. a failed timing probe) used to
    /// panic `quantile` via `partial_cmp().unwrap()`.
    #[test]
    fn nan_samples_are_ignored_not_fatal() {
        let s = Stats::from_slice(&[2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(s.median(), 2.0);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        // nothing but NaN -> NaN, still no panic
        let all_nan = Stats::from_slice(&[f64::NAN, f64::NAN]);
        assert!(all_nan.median().is_nan());
    }

    #[test]
    fn degenerate() {
        let s = Stats::from_slice(&[7.0]);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.median(), 7.0);
        assert!(Stats::new().mean().is_nan());
    }
}

//! Hand-rolled utility substrates.
//!
//! The offline vendor set on this image carries only the `xla` crate
//! closure plus `anyhow`, so the usual ecosystem crates (serde, rand,
//! csv, criterion) are reimplemented here at the scale this project
//! needs: a JSON parser/writer for the artifact manifest and run records,
//! a PCG64 RNG for data synthesis, descriptive statistics, timers, and a
//! CSV writer for experiment outputs.

pub mod csv;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use json::Json;
pub use rng::Pcg64;
pub use stats::Stats;
pub use timer::Timer;

//! PCG-64 (XSL-RR) pseudo-random generator + distribution helpers.
//!
//! Deterministic, seedable, stream-splittable — every dataset shard and
//! every replica derives an independent stream so runs reproduce exactly
//! across thread interleavings.

/// PCG XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id; distinct stream ids
    /// yield statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 1) | 1) ^ 0x5851_f42d_4c95_7f2d;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child stream (replica a, shard s, ...).
    pub fn split(&self, stream: u64) -> Self {
        Pcg64::new(self.peek_seed() ^ 0x9e37_79b9_7f4a_7c15, stream)
    }

    fn peek_seed(&self) -> u64 {
        (self.state >> 64) as u64 ^ self.state as u64
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is fine here;
        // modulo bias at n << 2^64 is negligible for data synthesis.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity — generation is not a hot path once data is cached).
    pub fn next_normal(&mut self) -> f32 {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.next_normal() * sigma;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (reservoir when k << n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

/// Fold a u64 seed into an i32 for artifact seed inputs. A plain
/// `as i32` cast drops bits 32..64 entirely, so runs whose seeds differ
/// only above bit 31 would collapse onto identical initializations and
/// dropout streams; xor-folding the high half in keeps every seed bit
/// influential.
pub fn fold_seed_i32(seed: u64) -> i32 {
    (((seed >> 32) ^ seed) as u32) as i32
}

/// SplitMix64 finalizer: full-avalanche 64-bit mix.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Per-step artifact seed shared by every training driver: mixes
/// (seed, round, replica, step) into the artifact's 31-bit seed space
/// with a full-avalanche hash per word.
///
/// The old ad-hoc derivations xor-shifted the round/step counters into
/// fixed bit positions (`round << 8 ^ replica`), which collides as soon
/// as a replica id reaches the shifted round bits (replica >= 256) or a
/// counter outgrows its field. Here every input word is avalanched and
/// the combination is order-sensitive (multiply + rotate between
/// words), so distinct (round, replica, step) tuples land on
/// structurally unrelated seeds at any scale.
pub fn step_seed(seed: u64, round: u64, replica: u64, step: u64) -> i32 {
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    for w in [round, replica, step] {
        h ^= mix64(w.wrapping_add(0x9E37_79B9_7F4A_7C15));
        h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9).rotate_left(27);
    }
    (mix64(h) & 0x7fff_ffff) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_seed_keeps_high_bits_influential() {
        let lo = 7u64;
        let hi = 7u64 | (1 << 40);
        assert_ne!(fold_seed_i32(lo), fold_seed_i32(hi));
        // seeds already in i32 range are unchanged
        assert_eq!(fold_seed_i32(7), 7);
        assert_eq!(fold_seed_i32(0), 0);
        // deterministic
        assert_eq!(fold_seed_i32(hi), fold_seed_i32(hi));
    }

    /// The regression the shared helper exists for: the old
    /// `(seed ^ round << 8 ^ replica)` derivation collided whenever a
    /// replica id overlapped the shifted round bits (replica 256 at
    /// round r == replica 0 at round r+1). Every tuple in a grid that
    /// crosses those boundaries must get a distinct seed.
    #[test]
    fn step_seed_distinct_across_replica_and_round_boundaries() {
        let mut seen = std::collections::HashMap::new();
        for &round in &[0u64, 1, 2, 255, 256, 257, 65535, 65536, 1 << 30] {
            for &replica in &[0u64, 1, 7, 255, 256, 257, 1023] {
                for step in 0..4u64 {
                    let s = step_seed(42, round, replica, step);
                    assert!((0..=i32::MAX).contains(&s));
                    if let Some(prev) =
                        seen.insert(s, (round, replica, step))
                    {
                        panic!(
                            "seed collision: {prev:?} vs \
                             {:?} -> {s}",
                            (round, replica, step)
                        );
                    }
                }
            }
        }
        // deterministic, and the base seed matters
        assert_eq!(step_seed(1, 2, 3, 4), step_seed(1, 2, 3, 4));
        assert_ne!(step_seed(1, 2, 3, 4), step_seed(2, 2, 3, 4));
        // order-sensitive: swapping round and replica moves the seed
        assert_ne!(step_seed(1, 5, 9, 0), step_seed(1, 9, 5, 0));
    }

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg64::new(1, 1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3, 3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5, 5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Pcg64::new(9, 2);
        for n in [1usize, 2, 7, 1000] {
            for _ in 0..200 {
                assert!(r.next_below(n) < n);
            }
        }
    }
}

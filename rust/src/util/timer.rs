//! Wall-clock timers and a lightweight scoped profiler.
//!
//! The coordinator attributes every training second to a phase
//! (`step`, `reduce`, `data`, `eval`) so the comm/compute ratio of the
//! paper's §4.1 can be reported directly from a run.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// The instant this timer started — for threads that must stamp
    /// events on the same clock (e.g. the engine's eval thread stamping
    /// curve points on the run's wall timer).
    pub fn started_at(&self) -> Instant {
        self.start
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Accumulates seconds per named phase; thread-safe.
#[derive(Debug, Default)]
pub struct PhaseProfiler {
    acc: Mutex<BTreeMap<String, (f64, u64)>>,
}

impl PhaseProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock the accumulator, shrugging off poison: a panicked thread
    /// mid-`add` can at worst lose its own increment, and the profiler
    /// is shared with the fabric's panic-free master loop — timing
    /// attribution must never become a second panic there.
    fn lock_acc(&self)
                -> std::sync::MutexGuard<'_, BTreeMap<String, (f64, u64)>> {
        self.acc.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn add(&self, phase: &str, seconds: f64) {
        self.add_many(phase, seconds, 1);
    }

    /// Merge a pre-aggregated total (restoring checkpointed phase
    /// accounting on resume).
    pub fn add_many(&self, phase: &str, seconds: f64, calls: u64) {
        let mut m = self.lock_acc();
        let e = m.entry(phase.to_string()).or_insert((0.0, 0));
        e.0 += seconds;
        e.1 += calls;
    }

    /// Run `f`, attributing its wall time to `phase`.
    pub fn scope<T>(&self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::new();
        let out = f();
        self.add(phase, t.elapsed_s());
        out
    }

    /// (total seconds, call count) per phase.
    pub fn snapshot(&self) -> BTreeMap<String, (f64, u64)> {
        self.lock_acc().clone()
    }

    pub fn total(&self, phase: &str) -> f64 {
        self.lock_acc().get(phase).map(|e| e.0).unwrap_or(0.0)
    }

    /// Ratio of `num` to `den` phase time (the paper's §4.1 comm/compute).
    pub fn ratio(&self, num: &str, den: &str) -> f64 {
        let d = self.total(den);
        if d == 0.0 {
            return f64::NAN;
        }
        self.total(num) / d
    }

    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::from("phase              total_s    calls   mean_ms\n");
        for (k, (s, n)) in &snap {
            out.push_str(&format!(
                "{:<18} {:>8.3} {:>8} {:>9.3}\n",
                k,
                s,
                n,
                if *n > 0 { s / *n as f64 * 1e3 } else { 0.0 }
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn profiler_accumulates() {
        let p = PhaseProfiler::new();
        p.add("step", 1.0);
        p.add("step", 2.0);
        p.add("reduce", 0.5);
        assert_eq!(p.total("step"), 3.0);
        assert!((p.ratio("reduce", "step") - 0.5 / 3.0).abs() < 1e-12);
        assert!(p.report().contains("step"));
    }

    #[test]
    fn add_many_merges_totals() {
        let p = PhaseProfiler::new();
        p.add("reduce", 1.0);
        p.add_many("reduce", 4.0, 9);
        assert_eq!(p.snapshot()["reduce"], (5.0, 10));
    }

    #[test]
    fn scope_returns_value() {
        let p = PhaseProfiler::new();
        let v = p.scope("x", || 42);
        assert_eq!(v, 42);
        assert!(p.total("x") >= 0.0);
        assert_eq!(p.snapshot()["x"].1, 1);
    }

    /// A thread panicking while holding the accumulator lock must not
    /// cascade: later `add`/`snapshot` calls recover the poisoned mutex
    /// instead of panicking the fabric's master loop.
    #[test]
    fn poisoned_lock_recovers() {
        let p = PhaseProfiler::new();
        p.add("step", 1.0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                let _guard = p.acc.lock().unwrap();
                panic!("poison the profiler");
            },
        ));
        assert!(r.is_err());
        assert!(p.acc.is_poisoned());
        p.add("step", 2.0); // must not panic
        assert_eq!(p.total("step"), 3.0);
        assert_eq!(p.snapshot()["step"].1, 2);
    }
}

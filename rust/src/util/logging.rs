//! Leveled stderr logging with a global verbosity switch.
//!
//! Kept deliberately simple (no `log` crate offline): the coordinator and
//! experiment drivers emit progress lines; benches run with logging off.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(2); // default Info

pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= VERBOSITY.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            &format!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            &format!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            &format!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}

//! Tiny CSV writer for experiment outputs (`runs/*.csv` are the series
//! behind every figure; EXPERIMENTS.md references them).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "csv row arity mismatch");
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn row_f64(&mut self, fields: &[f64]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|x| format!("{x}")).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Quote-free field sanitizer (we only ever write numbers and identifiers,
/// but keep commas from corrupting rows if a label sneaks one in).
pub fn sanitize(field: &str) -> String {
    field.replace(',', ";")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_rows() {
        let dir = std::env::temp_dir().join("parle_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x".into()]).unwrap();
            w.row_f64(&[2.5, 3.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,x\n2.5,3\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sanitizes() {
        assert_eq!(sanitize("a,b"), "a;b");
    }
}

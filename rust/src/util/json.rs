//! Minimal JSON parser + writer.
//!
//! Serde is not in the offline vendor set, so this module implements the
//! subset of JSON the project needs: the AOT `manifest.json`, run records
//! and experiment reports. Full RFC 8259 value grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null); no streaming.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------ accessors ---

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access with a helpful error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing json key {key:?} in {self:.0?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_of(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("json key {key:?} is not a string"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("json key {key:?} is not a number"))
    }

    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow!("json key {key:?} is not a number"))
    }

    // ----------------------------------------------------- construct ----

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ------------------------------------------------------- parse ------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ------------------------------------------------------- write ------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected , or }} found {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => bail!("expected , or ] found {other:?}"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"version": 1, "models": {"mlp": {"param_count": 6922,
            "artifacts": {"init": {"file": "mlp/init.hlo.txt",
            "inputs": [{"dtype": "i32", "shape": []}]}}}}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(
            j.req("models").unwrap().req("mlp").unwrap()
                .usize_of("param_count").unwrap(),
            6922
        );
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn numbers() {
        for (txt, want) in [
            ("0", 0.0),
            ("-12", -12.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5e-2", -0.025),
        ] {
            assert_eq!(Json::parse(txt).unwrap(), Json::Num(want));
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 2);
    }
}

//! Table 1 (§4): validation error (%) at wall-clock time for four
//! benchmark rows x four algorithms, plus the paper-scale wall-clock
//! columns from the Paleo-style performance model and the §4.1
//! comm/compute ratio check.

use anyhow::Result;

use crate::config::Algo;
use crate::experiments::{cell, fig2, fig3, fig4, print_table, ExpCtx};
use crate::perfmodel::comm::Link;
use crate::perfmodel::{algo_times, DeviceProfile, NetSpec};

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let algos = [
        (Algo::Parle, "Parle"),
        (Algo::ElasticSgd, "Elastic-SGD"),
        (Algo::EntropySgd, "Entropy-SGD"),
        (Algo::SgdDataParallel, "SGD"),
    ];

    let mut rows = Vec::new();
    // row 1: LeNet / MNIST (n=6 like the paper)
    {
        let mut cells = vec!["LeNet (MNIST, n=6)".to_string()];
        for (algo, _) in algos {
            let n = match algo {
                Algo::Parle | Algo::ElasticSgd => 6,
                Algo::SgdDataParallel => 3,
                _ => 1,
            };
            let rec = ctx.run_cached(fig2::base(ctx, algo, n),
                                     &format!("fig2_{}", algo.name()))?;
            cells.push(cell(&rec));
        }
        rows.push(cells);
    }
    // rows 2-3: WRN / CIFAR-10, CIFAR-100 (n=3)
    for model in ["wrn_cifar10", "wrn_cifar100"] {
        let mut cells = vec![format!("WRN ({model}, n=3)")];
        for (algo, _) in algos {
            let n = if matches!(algo, Algo::EntropySgd) { 1 } else { 3 };
            let rec = ctx.run_cached(
                fig3::base(ctx, model, algo, n),
                &format!("fig3_{model}_{}", algo.name()),
            )?;
            cells.push(cell(&rec));
        }
        rows.push(cells);
    }
    // row 4: WRN / SVHN
    {
        let mut cells = vec!["WRN (SVHN)".to_string()];
        for (algo, _) in algos {
            let n = if matches!(algo, Algo::EntropySgd) { 1 } else { 3 };
            let rec = ctx.run_cached(fig4::base(ctx, algo, n),
                                     &format!("fig4_{}", algo.name()))?;
            cells.push(cell(&rec));
        }
        rows.push(cells);
    }

    print_table(
        "TABLE 1 — validation error (%) at wall-clock (measured, \
         synthetic stand-ins)",
        &["Model", "Parle", "Elastic-SGD", "Entropy-SGD", "SGD"],
        &rows,
    );

    paper_scale_times();
    Ok(())
}

/// The paper-scale time columns (modeled; the *shape* check for the
/// "Time" half of Table 1 and the 2-4x speedup claim).
pub fn paper_scale_times() {
    let dev = DeviceProfile::titan_x_pascal();
    let link = Link::pcie3();
    let rows = [
        ("LeNet (MNIST)", NetSpec::lenet(), 60_000, 128, 6usize, 100.0,
         5.0),
        ("WRN-28-10 (CIFAR-10)", NetSpec::wrn(28, 10, 10), 50_000, 128, 3,
         200.0, 6.0),
        ("WRN-28-10 (CIFAR-100)", NetSpec::wrn(28, 10, 100), 50_000, 128,
         3, 200.0, 6.0),
        ("WRN-16-4 (SVHN)", NetSpec::wrn(16, 4, 10), 600_000, 128, 3,
         160.0, 4.0),
    ];
    let mut table = Vec::new();
    for (name, net, ds, b, n, e_sgd, e_parle) in rows {
        let est = algo_times(&net, ds, b, n, e_sgd, e_parle, &dev, &link);
        let f = |a: &str| {
            format!("{:.0} min", est.get(a).unwrap().minutes)
        };
        table.push(vec![
            name.to_string(),
            f("parle"),
            f("elastic-sgd"),
            f("entropy-sgd"),
            f("sgd"),
            format!("{:.2}x", est.parle_speedup_vs_sgd()),
        ]);
    }
    print_table(
        "TABLE 1 (time columns) — modeled at paper scale \
         (Titan-X + PCI-E, Paleo-style)",
        &["Model", "Parle", "Elastic", "Entropy", "SGD",
          "Parle speedup"],
        &table,
    );
}

/// §4.1 comm/compute: measured on a real run + modeled at paper scale.
pub fn run_comm(ctx: &ExpCtx) -> Result<()> {
    // measured: a short Parle run with the reduce profiler on
    let mut cfg = fig3::base(ctx, "wrn_cifar10", Algo::Parle, 3);
    cfg.epochs = ctx.epochs(1.0);
    let out = ctx.run(cfg, "comm_measured")?;
    println!(
        "\nmeasured comm/compute ratio (this testbed): {:.3}%  \
         ({} bytes moved)",
        out.record.comm_ratio * 100.0,
        out.record.comm_bytes
    );

    // modeled at paper scale (paper reports 0.52% for WRN-28-10 and
    // 0.43% for All-CNN)
    let link = Link::pcie3();
    for (name, net, step_s) in [
        ("WRN-28-10 (paper: 0.52%)", NetSpec::wrn(28, 10, 10), 0.528),
        ("All-CNN (paper: 0.43%)", NetSpec::allcnn(), 0.0326),
    ] {
        let reduce =
            crate::perfmodel::allreduce_time_s(net.param_count() * 4, 3,
                                               &link);
        let ratio = reduce / 25.0 / step_s;
        println!(
            "modeled {name}: reduce {:.2} ms / (L=25 x {:.0} ms step) \
             = {:.3}%",
            reduce * 1e3,
            step_s * 1e3,
            ratio * 100.0
        );
    }
    Ok(())
}

//! Fig. 4 (§4.4): WRN-16-4-style network on SVHN.
//!
//! Paper: all four algorithms land close (1.57-1.68%), Elastic-SGD
//! marginally best *with scoping* (without it, never below 1.9% — see
//! the `ablate-scoping` experiment).

use anyhow::Result;

use crate::config::{Algo, RunConfig};
use crate::experiments::ExpCtx;
use crate::opt::LrSchedule;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    for (algo, n) in [
        (Algo::Parle, 3),
        (Algo::ElasticSgd, 3),
        (Algo::EntropySgd, 1),
        (Algo::SgdDataParallel, 3),
    ] {
        let cfg = base(ctx, algo, n);
        let label = format!("fig4_{}", algo.name());
        ctx.run(cfg, &label)?;
    }
    Ok(())
}

pub fn base(ctx: &ExpCtx, algo: Algo, n: usize) -> RunConfig {
    let mut cfg = RunConfig::new("wrn_svhn", algo);
    cfg.replicas = n;
    cfg.epochs = ctx.epochs(3.0);
    cfg.data.train = ctx.examples(2048); // SVHN is the paper's big set
    cfg.data.val = 512;
    if cfg.l_steps > 1 {
        cfg.l_steps = 5;
    }
    cfg.data.seed = ctx.seed;
    cfg.seed = ctx.seed;
    // paper: lr 0.01, dropped 10x at [80,120] (SGD) / [2,4] (Parle)
    cfg.lr = LrSchedule::new(0.01, vec![2], 10.0);
    cfg.weight_decay = 5e-4;
    cfg.eval_every_rounds = if algo == Algo::SgdDataParallel { 20 } else { 4 };
    cfg
}

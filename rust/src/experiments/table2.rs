//! Table 2 (§5): splitting the dataset between replicas — All-CNN on
//! CIFAR-10. Rows: full data / 50% x n=3 / 25% x n=6; columns Parle,
//! Elastic-SGD, SGD.
//!
//! Paper: Parle(full) 5.18% < Elastic(full) 5.76% < SGD(full) 6.15%;
//! with splits, Parle degrades gracefully (5.89/6.08%) while subset-SGD
//! collapses (7.86/10.96%).

use anyhow::Result;

use crate::config::Algo;
use crate::experiments::{cell, fig6, print_table, ExpCtx};

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut rows = Vec::new();

    // full data row
    {
        let mut cells = vec!["All-CNN (full data)".to_string()];
        for algo in [Algo::Parle, Algo::ElasticSgd, Algo::SgdDataParallel] {
            let n = 3;
            let label = if algo == Algo::SgdDataParallel {
                "fig6_full_sgd".to_string()
            } else {
                format!("table2_full_{}", algo.name())
            };
            let rec = ctx.run_cached(fig6::base(ctx, algo, n), &label)?;
            cells.push(cell(&rec));
        }
        rows.push(cells);
    }

    // split rows
    for (tag, n, frac) in [("50% data", 3usize, 0.5f64),
                           ("25% data", 6, 0.25)] {
        let mut cells = vec![format!("All-CNN (n={n}, {tag})")];
        for algo in [Algo::Parle, Algo::ElasticSgd] {
            let mut cfg = fig6::base(ctx, algo, n);
            cfg.split_data = true;
            let fig6_tag = if n == 3 { "50pct" } else { "25pct" };
            let rec = ctx.run_cached(
                cfg,
                &format!("fig6_{}_{}", fig6_tag, algo.name()),
            )?;
            cells.push(cell(&rec));
        }
        // starred SGD-with-subset column
        let mut cfg = fig6::base(ctx, Algo::Sgd, 1);
        cfg.data.train = (cfg.data.train as f64 * frac) as usize;
        let fig6_tag = if n == 3 { "50pct" } else { "25pct" };
        let rec = ctx.run_cached(
            cfg,
            &format!("fig6_{}_sgd_subset", fig6_tag),
        )?;
        cells.push(format!("*{}", cell(&rec)));
        rows.push(cells);
    }

    print_table(
        "TABLE 2 — split-data validation error (%) at wall-clock \
         (* = SGD sees only a random subset)",
        &["Model", "Parle", "Elastic-SGD", "SGD"],
        &rows,
    );
    Ok(())
}

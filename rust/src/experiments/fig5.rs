//! Fig. 5 (§4.5): training error curves — SGD and Elastic-SGD drive the
//! training error to ~zero (overfit), while Parle and Entropy-SGD keep a
//! much larger training error yet generalize better ("flat minima exist
//! at higher energy levels").
//!
//! Reuses the fig3/fig4 run records when present (same runs, different
//! axis); otherwise runs a compact version itself.

use anyhow::Result;

use crate::config::Algo;
use crate::experiments::{fig3, fig4, ExpCtx};
use crate::util::csv::CsvWriter;
use crate::util::json::Json;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut rows = Vec::new();
    for (model, maker) in [
        ("wrn_cifar10", true),
        ("wrn_cifar100", true),
        ("wrn_svhn", false),
    ] {
        for (algo, n) in [
            (Algo::Parle, 3),
            (Algo::ElasticSgd, 3),
            (Algo::EntropySgd, 1),
            (Algo::SgdDataParallel, 3),
        ] {
            let prefix = if maker { "fig3" } else { "fig4" };
            let label = if maker {
                format!("{prefix}_{model}_{}", algo.name())
            } else {
                format!("{prefix}_{}", algo.name())
            };
            let path = format!("{}/{}.json", ctx.out_dir, label);
            let (train_err, train_loss) = match load_final(&path) {
                Some(v) => v,
                None => {
                    // record missing: run it now
                    let cfg = if maker {
                        fig3::base(ctx, model, algo, n)
                    } else {
                        fig4::base(ctx, algo, n)
                    };
                    let out = ctx.run(cfg, &label)?;
                    (
                        out.record.final_train_err,
                        out.record.final_train_loss,
                    )
                }
            };
            rows.push((model.to_string(), algo.name().to_string(),
                       train_err, train_loss));
        }
    }

    let mut w = CsvWriter::create(
        format!("{}/fig5_train_error.csv", ctx.out_dir),
        &["model", "algo", "train_err", "train_loss"],
    )?;
    println!("\nfig5: final training error (the paper's underfitting gap)");
    for (model, algo, err, loss) in &rows {
        w.row(&[
            model.clone(),
            algo.clone(),
            format!("{:.4}", err),
            format!("{:.4}", loss),
        ])?;
        println!("  {model:<14} {algo:<12} train err {:5.2}%  loss {:.3}",
                 err * 100.0, loss);
    }
    w.flush()?;
    Ok(())
}

fn load_final(path: &str) -> Option<(f64, f64)> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    Some((
        j.f64_of("final_train_err").ok()?,
        j.f64_of("final_train_loss").ok()?,
    ))
}

//! Fig. 3 (§4.3): WRN on CIFAR-10 (a) and CIFAR-100 (b) — validation
//! error vs wall-clock, n=3 replicas.
//!
//! Paper: Parle 3.24%/17.64% beats SGD 4.29%/18.85%, Entropy-SGD
//! 4.23%/19.05% and Elastic-SGD 4.38%/21.36%. Shape: Parle lowest final,
//! Elastic fast-but-worst on CIFAR-100.

use anyhow::Result;

use crate::config::{Algo, RunConfig};
use crate::experiments::ExpCtx;
use crate::opt::LrSchedule;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    for model in ["wrn_cifar10", "wrn_cifar100"] {
        println!("\n--- {model} ---");
        for (algo, n) in [
            (Algo::Parle, 3),
            (Algo::ElasticSgd, 3),
            (Algo::EntropySgd, 1),
            (Algo::SgdDataParallel, 3),
        ] {
            let cfg = base(ctx, model, algo, n);
            let label = format!("fig3_{model}_{}", algo.name());
            ctx.run(cfg, &label)?;
        }
    }
    Ok(())
}

pub fn base(ctx: &ExpCtx, model: &str, algo: Algo, n: usize) -> RunConfig {
    let mut cfg = RunConfig::new(model, algo);
    cfg.replicas = n;
    cfg.epochs = ctx.epochs(4.0);
    cfg.data.train = ctx.examples(1536);
    cfg.data.val = 512;
    if cfg.l_steps > 1 {
        cfg.l_steps = 5; // rounds/epoch matched to the paper's cadence
    }
    cfg.data.seed = ctx.seed;
    cfg.seed = ctx.seed;
    // paper: lr 0.1 dropped 5x at [60,120,180] (SGD) / [2,4,6] (Parle),
    // scaled to our budget
    cfg.lr = LrSchedule::new(0.1, vec![2, 3], 5.0);
    cfg.weight_decay = 5e-4;
    cfg.eval_every_rounds = if algo == Algo::SgdDataParallel { 20 } else { 4 };
    cfg
}

//! Fig. 6 + supporting runs for §5: All-CNN on CIFAR-10 with the
//! training set *split* across replicas.
//!
//! (a) n=3 replicas, 50% of data each; (b) n=6 replicas, 25% each.
//! Baselines: Elastic-SGD on the same shards; data-parallel SGD with the
//! full dataset; SGD with only a shard-sized random subset (the paper's
//! starred rows). Shape to hold: split-Parle beats subset-SGD decisively
//! and approaches full-data SGD.

use anyhow::Result;

use crate::config::{Algo, RunConfig};
use crate::experiments::ExpCtx;
use crate::opt::LrSchedule;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    for (tag, n, frac) in [("50pct", 3usize, 0.5f64), ("25pct", 6, 0.25)] {
        println!("\n--- fig6 {tag}: n={n}, {:.0}% data each ---",
                 frac * 100.0);
        // Parle + Elastic on disjoint shards
        for algo in [Algo::Parle, Algo::ElasticSgd] {
            let mut cfg = base(ctx, algo, n);
            cfg.split_data = true;
            let label = format!("fig6_{tag}_{}", algo.name());
            ctx.run(cfg, &label)?;
        }
        // SGD with a random subset of matching size (paper's "*" rows)
        let mut cfg = base(ctx, Algo::Sgd, 1);
        cfg.data.train = (cfg.data.train as f64 * frac) as usize;
        let label = format!("fig6_{tag}_sgd_subset");
        ctx.run(cfg, &label)?;
    }
    // full-data baseline (shared by both panels)
    let cfg = base(ctx, Algo::SgdDataParallel, 3);
    ctx.run(cfg, "fig6_full_sgd")?;
    Ok(())
}

pub fn base(ctx: &ExpCtx, algo: Algo, n: usize) -> RunConfig {
    let mut cfg = RunConfig::new("allcnn_cifar", algo);
    cfg.replicas = n;
    cfg.epochs = ctx.epochs(4.0);
    cfg.data.train = ctx.examples(1536);
    cfg.data.val = 512;
    if cfg.l_steps > 1 {
        cfg.l_steps = 5;
    }
    cfg.data.seed = ctx.seed;
    cfg.seed = ctx.seed;
    // paper (§5): All-CNN pipeline of Springenberg et al.: lr 0.1,
    // wd 1e-3, dropout 0.5 (baked into the model), flips+crops
    cfg.lr = LrSchedule::new(0.1, vec![2, 3], 5.0);
    cfg.weight_decay = 1e-3;
    cfg.eval_every_rounds = if matches!(algo, Algo::SgdDataParallel
                                        | Algo::Sgd) { 20 } else { 4 };
    cfg
}

//! Fig. 2 (§4.2): LeNet on MNIST — validation error vs wall-clock for
//! Parle (n=6), Elastic-SGD (n=6), Entropy-SGD and data-parallel SGD.
//!
//! Paper numbers at full scale: Parle 0.44%, Elastic 0.48%, Entropy
//! 0.49%, SGD 0.50%. The shape to reproduce on the synthetic stand-in:
//! Parle ends lowest; Elastic converges fastest early; SGD and Entropy
//! land close together above Parle.

use anyhow::Result;

use crate::config::{Algo, RunConfig};
use crate::experiments::ExpCtx;
use crate::opt::LrSchedule;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut rows = Vec::new();
    for (algo, n) in [
        (Algo::Parle, 6),
        (Algo::ElasticSgd, 6),
        (Algo::EntropySgd, 1),
        (Algo::SgdDataParallel, 3),
    ] {
        let cfg = base(ctx, algo, n);
        let label = format!("fig2_{}", algo.name());
        let out = ctx.run(cfg, &label)?;
        rows.push((algo.name(), out.record.final_val_err,
                   out.record.wall_s));
    }
    println!("\nfig2 summary (synthetic-MNIST stand-in):");
    for (algo, err, s) in &rows {
        println!("  {algo:<12} val {:.2}%  {:.0}s", err * 100.0, s);
    }
    Ok(())
}

pub fn base(ctx: &ExpCtx, algo: Algo, n: usize) -> RunConfig {
    let mut cfg = RunConfig::new("lenet_mnist", algo);
    cfg.replicas = n;
    cfg.epochs = ctx.epochs(4.0);
    cfg.data.train = ctx.examples(1536);
    cfg.data.val = 512;
    // L scaled so rounds-per-epoch matches the paper's cadence
    // (paper: 390 bpe / L=25 ~ 16 rounds/epoch; here: 48 bpe / L=5 ~ 10)
    if cfg.l_steps > 1 {
        cfg.l_steps = 5;
    }
    cfg.data.seed = ctx.seed;
    cfg.seed = ctx.seed;
    // paper: lr 0.1, dropped 10x after epoch 2 for Parle/Entropy, at
    // [30,60,90] for SGD (scaled to our shorter budget)
    cfg.lr = match algo {
        Algo::Parle | Algo::EntropySgd => {
            LrSchedule::new(0.1, vec![2], 10.0)
        }
        _ => LrSchedule::new(0.1, vec![2, 3], 10.0),
    };
    cfg.weight_decay = 0.0; // paper uses none on MNIST
    cfg.eval_every_rounds = if algo == Algo::SgdDataParallel { 20 } else { 4 };
    cfg
}

//! Ablations over the design choices DESIGN.md §7 calls out.

use anyhow::Result;

use crate::config::{Algo, ScopingCfg};
use crate::experiments::{fig3, fig4, print_table, ExpCtx};

/// §4.4: Elastic-SGD with vs without scoping (paper: SVHN never goes
/// below 1.9% without scoping vs 1.57% with).
pub fn scoping(ctx: &ExpCtx) -> Result<()> {
    let mut rows = Vec::new();
    for (tag, scoping) in [
        ("scoping=paper", ScopingCfg::Paper),
        ("scoping=off", ScopingCfg::Constant { gamma: 100.0, rho: 1.0 }),
    ] {
        let mut cfg = fig4::base(ctx, Algo::ElasticSgd, 3);
        cfg.scoping = scoping;
        let out = ctx.run(cfg, &format!("ablate_scoping_{tag}"))?;
        rows.push(vec![
            tag.to_string(),
            format!("{:.2}%", out.record.final_val_err * 100.0),
        ]);
    }
    print_table("ablation: Elastic-SGD scoping (§4.4)",
                &["variant", "val err"], &rows);
    Ok(())
}

/// §4.3: Parle with n in {3, 6, 8}: initial speedup but worse final
/// error at n=8 with the same hyper-parameters.
pub fn replicas(ctx: &ExpCtx) -> Result<()> {
    let mut rows = Vec::new();
    for n in [3usize, 6, 8] {
        let cfg = {
            let mut c = fig3::base(ctx, "wrn_cifar10", Algo::Parle, n);
            c.epochs = ctx.epochs(2.0);
            c
        };
        let out = ctx.run(cfg, &format!("ablate_replicas_n{n}"))?;
        rows.push(vec![
            format!("n={n}"),
            format!("{:.2}%", out.record.final_val_err * 100.0),
            format!("{:.0}s", out.record.wall_s),
        ]);
    }
    print_table("ablation: replica count (§4.3)",
                &["variant", "val err", "wall"], &rows);
    Ok(())
}

/// Communication period L: more local work per reduce trades error for
/// communication (L=1 is Elastic-like, L=100 nearly uncoupled).
pub fn l_sweep(ctx: &ExpCtx) -> Result<()> {
    let mut rows = Vec::new();
    for l in [1usize, 5, 25, 100] {
        let mut cfg = fig3::base(ctx, "wrn_cifar10", Algo::Parle, 3);
        cfg.l_steps = l;
        cfg.epochs = ctx.epochs(2.0);
        cfg.eval_every_rounds = (25 / l).max(1);
        let out = ctx.run(cfg, &format!("ablate_l_{l}"))?;
        rows.push(vec![
            format!("L={l}"),
            format!("{:.2}%", out.record.final_val_err * 100.0),
            format!("{:.2}%", out.record.comm_ratio * 100.0),
        ]);
    }
    print_table("ablation: communication period L",
                &["variant", "val err", "comm ratio"], &rows);
    Ok(())
}

//! Experiment drivers — one per paper table/figure (DESIGN.md §6 maps
//! each to its source section).
//!
//! Every driver writes per-run JSON records and figure CSVs under
//! `runs/` and prints the table/series the paper reports. `--quick`
//! shrinks datasets/epochs ~4x for smoke runs; full runs are what
//! EXPERIMENTS.md records.
//!
//! All training goes through the [`crate::coordinator::RoundEngine`],
//! so every experiment inherits overlapped evaluation and — for long
//! runs — round-granular checkpointing (`cfg.checkpoint_every_rounds`
//! + `--resume` on the `train` subcommand).

pub mod ablations;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table1;
pub mod table2;

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::coordinator::{train, TrainOutput};
use crate::metrics::RunRecord;

/// Shared context for every driver.
#[derive(Clone, Debug)]
pub struct ExpCtx {
    pub artifacts_dir: String,
    pub out_dir: String,
    /// Shrink workloads ~4x (CI/smoke mode).
    pub quick: bool,
    pub seed: u64,
}

impl Default for ExpCtx {
    fn default() -> Self {
        ExpCtx {
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
            quick: false,
            seed: 42,
        }
    }
}

impl ExpCtx {
    /// Scale an epoch budget for quick mode.
    pub fn epochs(&self, full: f64) -> f64 {
        if self.quick {
            (full / 4.0).max(0.25)
        } else {
            full
        }
    }

    /// Scale a dataset size for quick mode.
    pub fn examples(&self, full: usize) -> usize {
        if self.quick {
            (full / 4).max(256)
        } else {
            full
        }
    }

    /// Run one config, save its record, return output.
    pub fn run(&self, mut cfg: RunConfig, label: &str)
               -> Result<TrainOutput> {
        cfg.artifacts_dir = self.artifacts_dir.clone();
        let out = train(&cfg, label)?;
        out.record.save(&self.out_dir)?;
        out.record
            .curve
            .write_csv(&format!("{}/{}.csv", self.out_dir,
                                label.replace('/', "_")),
                       label)?;
        println!("  {}", out.record.summary());
        Ok(out)
    }

    /// Like [`run`], but reuses a saved record if one exists under this
    /// label (lets `table1`/`table2`/`fig5` share runs with the figure
    /// drivers instead of recomputing them).
    pub fn run_cached(&self, cfg: RunConfig, label: &str)
                      -> Result<RunRecord> {
        let path = format!("{}/{}.json", self.out_dir,
                           label.replace('/', "_"));
        if let Some(rec) = load_record(&path) {
            println!("  (cached) {}", rec.summary());
            return Ok(rec);
        }
        Ok(self.run(cfg, label)?.record)
    }
}

/// Names every driver answers to.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("fig1", "overlap of independently trained networks (§1.2)"),
    ("fig2", "LeNet/MNIST validation error vs wall-clock (§4.2)"),
    ("fig3", "WRN CIFAR-10/100 validation error vs wall-clock (§4.3)"),
    ("fig4", "WRN SVHN validation error vs wall-clock (§4.4)"),
    ("fig5", "training error curves / underfitting (§4.5)"),
    ("fig6", "All-CNN split-data curves (§5)"),
    ("table1", "summary errors+times, 4 datasets x 4 algos (§4)"),
    ("table2", "split-data summary (§5)"),
    ("comm", "comm/compute ratio measured + modeled (§4.1)"),
    ("sec32", "deputies-under-a-sheriff hierarchy, eq. 10 (§3.2)"),
    ("ablate-scoping", "Elastic-SGD with/without scoping (§4.4)"),
    ("ablate-replicas", "Parle with n in {3,6,8} (§4.3)"),
    ("ablate-l", "communication period L sweep"),
];

/// Dispatch by name ("all" runs the full suite in paper order).
pub fn run_experiment(name: &str, ctx: &ExpCtx) -> Result<()> {
    match name {
        "fig1" => fig1::run(ctx),
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig6::run(ctx),
        "table1" => table1::run(ctx),
        "table2" => table2::run(ctx),
        "comm" => table1::run_comm(ctx),
        "sec32" => run_sec32(ctx),
        "ablate-scoping" => ablations::scoping(ctx),
        "ablate-replicas" => ablations::replicas(ctx),
        "ablate-l" => ablations::l_sweep(ctx),
        "all" => {
            for (n, _) in EXPERIMENTS {
                println!("\n==== experiment {n} ====");
                run_experiment(n, ctx)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; see `parle list`"),
    }
}

/// Markdown-ish table printer used by the table drivers.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n{title}");
    println!("{}", header.join(" | "));
    println!("{}", header.iter().map(|_| "---").collect::<Vec<_>>()
             .join(" | "));
    for r in rows {
        println!("{}", r.join(" | "));
    }
}

/// §3.2 hierarchy: 2 deputies x 2 workers vs flat Parle with 4 replicas
/// at the same gradient budget — eq. (10) says they optimize equivalent
/// objectives; the hierarchy trades a second coupling level for
/// deployment flexibility (deputies can live on different machines).
fn run_sec32(ctx: &ExpCtx) -> Result<()> {
    use crate::coordinator::train_hierarchical;
    let mut cfg = RunConfig::new("mlp_synth", crate::config::Algo::Parle);
    cfg.artifacts_dir = ctx.artifacts_dir.clone();
    cfg.epochs = ctx.epochs(8.0);
    cfg.l_steps = 2;
    cfg.data.train = ctx.examples(1024);
    cfg.data.val = 512;
    cfg.seed = ctx.seed;
    cfg.data.seed = ctx.seed;
    cfg.eval_every_rounds = 8;

    let out = train_hierarchical(&cfg, 2, 2, "sec32_deputies")?;
    out.record.save(&ctx.out_dir)?;
    println!("  {}", out.record.summary());

    let mut flat = cfg.clone();
    flat.replicas = 4;
    let rec = ctx.run(flat, "sec32_flat_parle")?.record;
    println!(
        "\nsec3.2: hierarchy {:.2}% vs flat parle {:.2}% (equivalent \
         objectives; eq. 10)",
        out.record.final_val_err * 100.0,
        rec.final_val_err * 100.0
    );
    Ok(())
}

/// Load a previously saved run record (minimal fields + curve).
pub fn load_record(path: &str) -> Option<RunRecord> {
    use crate::metrics::{Curve, CurvePoint};
    use crate::util::json::Json;
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    let mut curve = Curve::new();
    for p in j.get("curve")?.as_arr()? {
        let a = p.as_arr()?;
        if a.len() == 5 {
            curve.push(CurvePoint {
                wall_s: a[0].as_f64()?,
                epoch: a[1].as_f64()?,
                train_loss: a[2].as_f64()?,
                train_err: a[3].as_f64()?,
                val_err: a[4].as_f64()?,
            });
        }
    }
    Some(RunRecord {
        label: j.str_of("label").ok()?.to_string(),
        model: j.str_of("model").ok()?.to_string(),
        algo: j.str_of("algo").ok()?.to_string(),
        replicas: j.usize_of("replicas").ok()?,
        curve,
        wall_s: j.f64_of("wall_s").ok()?,
        final_val_err: j.f64_of("final_val_err").ok()?,
        final_train_err: j.f64_of("final_train_err").ok()?,
        final_train_loss: j.f64_of("final_train_loss").ok()?,
        comm_bytes: j.f64_of("comm_bytes").ok()? as u64,
        comm_ratio: j.f64_of("comm_ratio").ok()?,
        phases: Default::default(),
    })
}

/// Format "err% (time s)" cells like the paper's tables.
pub fn cell(rec: &RunRecord) -> String {
    format!(
        "{:.2}% ({:.0}s)",
        rec.final_val_err * 100.0,
        rec.wall_s
    )
}

//! Fig. 1 + §1.2: train several All-CNNs independently, then measure
//! (a) the permutation-invariant overlap per layer after greedy
//! alignment (Fig. 1) and (b) the validation error of: each individual
//! net, the softmax ensemble, the naive one-shot weight average, and the
//! aligned weight average.
//!
//! Paper numbers (full scale, 6 nets): individuals ~8.0%, ensemble
//! 7.84%, naive average 89.9% (chance), aligned average 18.7%. The shape
//! to reproduce: naive average ~ chance, aligned average dramatically
//! better, ensemble slightly better than individuals.

use anyhow::Result;

use crate::align::{align_to, average_params, ConvStack};
use crate::config::{Algo, RunConfig};
use crate::coordinator::driver::{evaluate, lm_seq_len};
use crate::data::batcher::{Augment, Batcher};
use crate::data::build;
use crate::experiments::{fig6, ExpCtx};
use crate::runtime::{lit_f32, Session};
use crate::util::csv::CsvWriter;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let n_nets = if ctx.quick { 3 } else { 6 };
    println!("training {n_nets} independent All-CNNs (sequential SGD)...");

    let mut nets: Vec<Vec<f32>> = Vec::new();
    let mut indiv_errs = Vec::new();
    for i in 0..n_nets {
        let mut cfg: RunConfig = fig6::base(ctx, Algo::Sgd, 1);
        cfg.seed = ctx.seed + 1000 * (i as u64 + 1);
        cfg.data.seed = ctx.seed; // same dataset, different init/order
        let out = ctx.run(cfg, &format!("fig1_net{i}"))?;
        indiv_errs.push(out.record.final_val_err);
        nets.push(out.final_params);
    }

    // --- evaluation setup -------------------------------------------------
    let session = Session::open(&ctx.artifacts_dir)?;
    let mm = session.manifest.model("allcnn_cifar")?.clone();
    let mut data_cfg = crate::data::DataConfig {
        train: 64,
        val: 1024,
        difficulty: 0.35,
        seed: ctx.seed,
    };
    data_cfg.seed = ctx.seed;
    let (_, val_ds) = build(&mm.dataset, &data_cfg)?;
    let eval_batches = Batcher::new(
        &val_ds,
        mm.batch,
        lm_seq_len(&mm),
        Augment::none(),
        ctx.seed,
        0xe,
    )
    .eval_batches();

    let eval = |params: &[f32]| -> Result<f64> {
        evaluate(&session, "allcnn_cifar", &mm, params, &eval_batches)
    };

    // --- ensembles & averages ----------------------------------------------
    let naive_avg = average_params(&nets);
    let naive_err = eval(&naive_avg)?;

    let stack = ConvStack::from_layer_table(&mm.layers)?;
    let mut aligned = vec![nets[0].clone()];
    let mut overlaps_before = Vec::new();
    let mut overlaps_after = Vec::new();
    for net in &nets[1..] {
        let (a, report) = align_to(&stack, &nets[0], net);
        aligned.push(a);
        overlaps_before.push(report.iter().map(|r| r.1).collect::<Vec<_>>());
        overlaps_after.push(report.iter().map(|r| r.2).collect::<Vec<_>>());
    }
    let aligned_avg = average_params(&aligned);
    let aligned_err = eval(&aligned_avg)?;

    let ensemble_err = softmax_ensemble_err(&session, &mm, &nets,
                                            &eval_batches)?;

    // --- report -------------------------------------------------------------
    let mean_indiv =
        indiv_errs.iter().sum::<f64>() / indiv_errs.len() as f64;
    println!("\nfig1 / §1.2 results ({} nets):", n_nets);
    println!("  individual nets:  {:.2}% mean", mean_indiv * 100.0);
    println!("  softmax ensemble: {:.2}%", ensemble_err * 100.0);
    println!("  naive average:    {:.2}%  (chance = {:.1}%)",
             naive_err * 100.0,
             (1.0 - 1.0 / mm.num_classes as f64) * 100.0);
    println!("  aligned average:  {:.2}%", aligned_err * 100.0);

    // per-layer overlap CSV (the Fig-1 series)
    let layer_names: Vec<String> = stack.layers
        [..stack.layers.len() - 1]
        .iter()
        .map(|l| l.name.clone())
        .collect();
    let mut w = CsvWriter::create(
        format!("{}/fig1_overlap.csv", ctx.out_dir),
        &["layer", "overlap_before_mean", "overlap_after_mean"],
    )?;
    println!("\n  per-layer overlap (before -> after alignment):");
    for (li, name) in layer_names.iter().enumerate() {
        let before: f64 = overlaps_before.iter().map(|o| o[li]).sum::<f64>()
            / overlaps_before.len() as f64;
        let after: f64 = overlaps_after.iter().map(|o| o[li]).sum::<f64>()
            / overlaps_after.len() as f64;
        w.row(&[name.clone(), format!("{before:.4}"),
                format!("{after:.4}")])?;
        println!("    {name:<6} {before:6.3} -> {after:6.3}");
    }
    w.flush()?;

    // summary CSV
    let mut w = CsvWriter::create(
        format!("{}/fig1_summary.csv", ctx.out_dir),
        &["variant", "val_err"],
    )?;
    for (k, v) in [
        ("individual_mean", mean_indiv),
        ("ensemble", ensemble_err),
        ("naive_average", naive_err),
        ("aligned_average", aligned_err),
    ] {
        w.row(&[k.to_string(), format!("{v:.5}")])?;
    }
    w.flush()?;
    Ok(())
}

/// Error of averaging the nets' softmax predictions (the classic
/// test-time ensemble the paper compares against).
fn softmax_ensemble_err(
    session: &Session,
    mm: &crate::runtime::ModelManifest,
    nets: &[Vec<f32>],
    batches: &[crate::data::batcher::Batch],
) -> Result<f64> {
    let p = mm.param_count;
    let c = mm.num_classes;
    let mut wrong = 0usize;
    let mut total = 0usize;
    for b in batches {
        let mut probs = vec![0.0f64; b.n * c];
        for net in nets {
            let (xb, _) = crate::coordinator::replica::batch_literals(mm, b)?;
            let outs = session.execute(
                &mm.name,
                "predict",
                &[lit_f32(net, &[p])?, xb],
            )?;
            let logits = crate::runtime::to_f32(&outs[0])?;
            for i in 0..b.n {
                // softmax per example
                let row = &logits[i * c..(i + 1) * c];
                let m = row.iter().cloned().fold(f32::MIN, f32::max);
                let exps: Vec<f64> =
                    row.iter().map(|&x| ((x - m) as f64).exp()).collect();
                let s: f64 = exps.iter().sum();
                for j in 0..c {
                    probs[i * c + j] += exps[j] / s;
                }
            }
        }
        for i in 0..b.n {
            let row = &probs[i * c..(i + 1) * c];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 != b.y[i] {
                wrong += 1;
            }
            total += 1;
        }
    }
    Ok(wrong as f64 / total.max(1) as f64)
}

//! §1.2 motivation experiment as a standalone example: train a few
//! All-CNNs independently, then show why naive weight averaging fails
//! and permutation-aligned averaging doesn't — the observation that
//! motivates Parle's quadratic coupling.
//!
//! ```bash
//! make artifacts && cargo run --release --example ensemble_averaging
//! ```

use parle::align::{align_to, average_params, ConvStack};
use parle::config::{Algo, RunConfig};
use parle::coordinator::driver::{evaluate, lm_seq_len};
use parle::coordinator::train;
use parle::data::batcher::{Augment, Batcher};
use parle::data::{build, DataConfig};
use parle::opt::LrSchedule;
use parle::runtime::Session;

fn main() -> parle::Result<()> {
    let n_nets = 3;
    let seed = 42u64;

    // --- train independent nets ------------------------------------------
    let mut nets = Vec::new();
    for i in 0..n_nets {
        let mut cfg = RunConfig::new("allcnn_cifar", Algo::Sgd);
        cfg.epochs = 3.0;
        cfg.data.train = 2048;
        cfg.data.val = 512;
        cfg.data.seed = seed; // same data
        cfg.seed = seed + 1000 * (i + 1); // different init + order
        cfg.lr = LrSchedule::new(0.1, vec![2], 5.0);
        cfg.weight_decay = 1e-3;
        cfg.eval_every_rounds = 0;
        cfg.artifacts_dir = "artifacts".into();
        let out = train(&cfg, &format!("ens_net{i}"))?;
        println!(
            "net {i}: val err {:.2}%",
            out.record.final_val_err * 100.0
        );
        nets.push(out.final_params);
    }

    // --- evaluate combinations --------------------------------------------
    let session = Session::open("artifacts")?;
    let mm = session.manifest.model("allcnn_cifar")?.clone();
    let (_, val) = build(
        &mm.dataset,
        &DataConfig {
            train: 64,
            val: 512,
            difficulty: 0.35,
            seed,
        },
    )?;
    let batches = Batcher::new(&val, mm.batch, lm_seq_len(&mm),
                               Augment::none(), seed, 0xe)
        .eval_batches();
    let eval = |p: &[f32]| {
        evaluate(&session, "allcnn_cifar", &mm, p, &batches)
    };

    let naive = average_params(&nets);
    println!("\nnaive weight average:   {:.2}%  (paper: ~chance)",
             eval(&naive)? * 100.0);

    let stack = ConvStack::from_layer_table(&mm.layers)?;
    let mut aligned = vec![nets[0].clone()];
    for net in &nets[1..] {
        let (a, report) = align_to(&stack, &nets[0], net);
        let mean_after: f64 = report.iter().map(|r| r.2).sum::<f64>()
            / report.len() as f64;
        println!("aligned one net: mean filter overlap after matching \
                  {mean_after:.3}");
        aligned.push(a);
    }
    let avg = average_params(&aligned);
    println!("aligned weight average: {:.2}%  (paper: far better than \
              naive)", eval(&avg)? * 100.0);
    Ok(())
}

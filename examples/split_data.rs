//! §5 scenario as a standalone example: split the training set across
//! replicas so each sees only a disjoint shard, and compare Parle with
//! (a) Elastic-SGD on the same shards and (b) SGD that only gets one
//! shard-sized subset.
//!
//! The interesting output: split-Parle stays close to the full-data
//! baseline because the proximal term ferries information between
//! shards — the paper's federated-learning-flavored result.
//!
//! ```bash
//! make artifacts && cargo run --release --example split_data
//! ```

use parle::config::{Algo, RunConfig};
use parle::coordinator::train;
use parle::opt::LrSchedule;

fn base(algo: Algo, n: usize) -> RunConfig {
    let mut cfg = RunConfig::new("allcnn_cifar", algo);
    cfg.replicas = n;
    cfg.epochs = 3.0;
    cfg.data.train = 2048;
    cfg.data.val = 512;
    cfg.lr = LrSchedule::new(0.1, vec![2], 5.0);
    cfg.weight_decay = 1e-3;
    cfg.eval_every_rounds = 2;
    cfg.artifacts_dir = "artifacts".into();
    cfg
}

fn main() -> parle::Result<()> {
    let n = 3;
    println!("== split-data: n={n} replicas, each sees 1/{n} of the set ==");

    let mut rows = Vec::new();

    let mut cfg = base(Algo::Parle, n);
    cfg.split_data = true;
    let out = train(&cfg, "split_parle")?;
    rows.push(("parle (split)", out.record.final_val_err));

    let mut cfg = base(Algo::ElasticSgd, n);
    cfg.split_data = true;
    let out = train(&cfg, "split_elastic")?;
    rows.push(("elastic (split)", out.record.final_val_err));

    let mut cfg = base(Algo::Sgd, 1);
    cfg.data.train /= n; // subset-SGD: sees only one shard's worth
    let out = train(&cfg, "split_sgd_subset")?;
    rows.push(("sgd (1/n subset)", out.record.final_val_err));

    let cfg = base(Algo::SgdDataParallel, n);
    let out = train(&cfg, "split_sgd_full")?;
    rows.push(("sgd-dp (full data)", out.record.final_val_err));

    println!("\nresults:");
    for (name, err) in &rows {
        println!("  {name:<20} val err {:.2}%", err * 100.0);
    }
    println!(
        "\nshape check (paper Table 2): parle(split) < sgd(subset), \
         and parle(split) within reach of sgd(full)."
    );
    Ok(())
}

//! Quickstart: train a small MLP on synthetic 10-class features with
//! Parle (n=3 replicas) and compare against plain SGD — the 60-second
//! tour of the public API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use parle::config::{Algo, RunConfig};
use parle::coordinator::train;
use parle::opt::LrSchedule;

fn main() -> parle::Result<()> {
    // one config per algorithm, identical budgets
    let mut results = Vec::new();
    for algo in [Algo::Parle, Algo::Sgd] {
        let mut cfg = RunConfig::new("mlp_synth", algo);
        cfg.replicas = if algo == Algo::Parle { 3 } else { 1 };
        cfg.epochs = 6.0;
        cfg.l_steps = if algo == Algo::Parle { 5 } else { 1 };
        cfg.data.train = 2048;
        cfg.data.val = 512;
        cfg.lr = LrSchedule::new(0.1, vec![3, 5], 5.0);
        cfg.eval_every_rounds = 10;
        cfg.artifacts_dir = "artifacts".into();

        let out = train(&cfg, &format!("quickstart_{}", algo.name()))?;
        println!(
            "{:<8} final val err {:.2}%  (wall {:.1}s, comm {:.2}%)",
            algo.name(),
            out.record.final_val_err * 100.0,
            out.record.wall_s,
            out.record.comm_ratio * 100.0
        );
        println!("         curve: {}", out.record.curve.sparkline());
        results.push((algo, out.record.final_val_err));
    }

    // Parle should do at least as well as the sequential baseline
    let parle_err = results[0].1;
    let sgd_err = results[1].1;
    println!(
        "\nParle {:.2}% vs SGD {:.2}% — the paper's claim is that the \
         replica ensemble + flat-minima bias generalizes better.",
        parle_err * 100.0,
        sgd_err * 100.0
    );
    Ok(())
}

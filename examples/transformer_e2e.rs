//! End-to-end driver (mandated by the reproduction brief): train a
//! decoder-only transformer LM with Parle on a synthetic character
//! corpus for a few hundred steps and log the loss curve.
//!
//! Exercises the full stack: synthetic corpus -> rust batcher -> AOT
//! transformer artifacts (Pallas matmul kernels inside) -> replica
//! threads -> elastic reduce -> eval. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example transformer_e2e
//! ```

use parle::config::{Algo, RunConfig};
use parle::coordinator::train;
use parle::opt::LrSchedule;

fn main() -> parle::Result<()> {
    let mut cfg = RunConfig::new("transformer_lm", Algo::Parle);
    cfg.replicas = 2;
    cfg.l_steps = 4;
    cfg.epochs = 2.0;
    cfg.data.train = 512; // windows per epoch
    cfg.data.val = 128;
    cfg.lr = LrSchedule::new(0.05, vec![2], 5.0);
    cfg.weight_decay = 1e-4;
    cfg.eval_every_rounds = 2;
    cfg.artifacts_dir = "artifacts".into();

    println!(
        "training {} (P=818k) with Parle n={} ({} steps/replica)...",
        cfg.model,
        cfg.replicas,
        (cfg.epochs * 512.0 / 16.0) as u64
    );
    let out = train(&cfg, "transformer_e2e")?;

    println!("\nloss curve (train loss in nats/token):");
    for p in &out.record.curve.points {
        println!(
            "  wall {:7.1}s  epoch {:.2}  train loss {:.4}  \
             val err {:.1}%",
            p.wall_s,
            p.epoch,
            p.train_loss,
            p.val_err * 100.0
        );
    }
    let first = out.record.curve.points.first().unwrap();
    let last = out.record.curve.points.last().unwrap();
    println!(
        "\ntrain loss {:.3} -> {:.3} nats/token over {:.0}s \
         (unigram entropy of the synthetic corpus ~ {:.1} nats)",
        first.train_loss,
        last.train_loss,
        out.record.wall_s,
        (64f64).ln()
    );
    out.record.save("runs")?;
    out.record
        .curve
        .write_csv("runs/transformer_e2e.csv", "transformer_e2e")?;
    Ok(())
}
